//! The measurement harness: run one [`BenchDef`] and produce one
//! [`Measurement`].
//!
//! Every workload family from [`Workload`] compiles down to a closure
//! returning the output vector, so timing and checksumming are uniform:
//! warmup runs first (the first one checksums the output), then
//! `samples` timed runs, then mean/stddev/min in nanoseconds.
//!
//! [`check_defs`] is the correctness half — run each definition once
//! and compare the observed checksum against the pinned one, no timing.
//! [`measure_in_child`] is the isolation half — re-exec the current
//! binary (`prunemap bench --child`) so one measurement per process and
//! no benchmark warms allocator pools, thread pools, or caches for the
//! next; the child speaks a one-line `RECORD {json}` stdout protocol.

use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::checksum_f32s;
use super::defs::{BenchDef, Workload};
use super::records::{git_rev, Measurement};
use crate::pruning::{prune, PatternLibrary, Scheme};
use crate::rng::Rng;
use crate::runtime::graph::im2col::{im2col, Im2colPanels};
use crate::runtime::GraphExecutor;
use crate::serve::{InferRequest, ModelRegistry, PreparedModel, Server, Session};
use crate::sparse::{permute_rows, reorder_rows, Bcs, Engine, SparseKernel};
use crate::telemetry::TraceRing;
use crate::tensor::Tensor;
use crate::util::bench::black_box;

/// Prune-and-mask a weight tensor (identity for `Scheme::None`).
fn masked(w: &Tensor, scheme: &Scheme, compression: f32, lib: &PatternLibrary) -> Tensor {
    match scheme {
        Scheme::None => w.clone(),
        _ => {
            let r = prune(w, scheme, compression, lib);
            w.hadamard(&r.mask)
        }
    }
}

fn prepared_for(model: &str, dataset: &str, method: &str, seed: u64) -> Result<PreparedModel> {
    PreparedModel::builder()
        .model(model)
        .dataset(dataset)
        .method(method)
        .seed(seed)
        .build()
        .with_context(|| format!("prepare model '{model}' on '{dataset}'"))
}

/// Compile a definition to a run-once closure returning the output the
/// checksum pins.  All expensive setup (pruning, compilation, session
/// spin-up) happens here, outside the timed region — the closure is the
/// steady-state hot path only.
fn build_runner(def: &BenchDef) -> Result<Box<dyn FnMut() -> Vec<f32>>> {
    let lib = PatternLibrary::default8();
    let mut rng = Rng::new(def.seed);
    let engine = Engine::new(def.threads).with_tile_cols(def.tile);
    match &def.workload {
        Workload::Spmm { rows, cols, scheme, compression } => {
            let w = Tensor::he_normal(&[*rows, *cols], *cols, &mut rng);
            let t = masked(&w, scheme, *compression, &lib);
            let t = permute_rows(&t, &reorder_rows(&t));
            let kernel = Bcs::from_dense(&t);
            let batch = def.batch;
            let x: Vec<f32> = (0..cols * batch).map(|i| (i as f32 * 0.11).sin()).collect();
            let scalar = def.engine == "scalar";
            Ok(Box::new(move || {
                if scalar {
                    kernel.spmm_scalar(&x, batch)
                } else {
                    engine.spmm(&kernel, &x, batch)
                }
            }))
        }
        Workload::Conv { in_ch, out_ch, hw, scheme, compression } => {
            let w = Tensor::he_normal(&[*out_ch, *in_ch, 3, 3], in_ch * 9, &mut rng);
            let convw = masked(&w, scheme, *compression, &lib).conv_to_gemm().transpose2();
            let kernel = Bcs::from_dense(&convw);
            let (c, s, batch) = (*in_ch, *hw, def.batch);
            let act: Vec<f32> =
                (0..c * batch * s * s).map(|i| ((i % 13) as f32) * 0.3 - 1.8).collect();
            let fused = def.engine == "fused";
            let mut xmat = Vec::new();
            Ok(Box::new(move || {
                if fused {
                    // the panel view is a lazy re-index over `act`;
                    // rebuilding it per run costs nothing and keeps the
                    // closure self-contained
                    let panels = Im2colPanels::new(&act, c, s, s, batch, 3, 3, 1);
                    engine.spmm_fused(&kernel, &panels)
                } else {
                    let (oh, ow) = im2col(&act, c, s, s, batch, 3, 3, 1, &mut xmat);
                    engine.spmm(&kernel, &xmat, batch * oh * ow)
                }
            }))
        }
        Workload::Infer { model, dataset, method } => {
            let prepared = prepared_for(model, dataset, method, def.seed)?;
            let exec = match def.engine.as_str() {
                "serial" => GraphExecutor::serial().with_tile_cols(def.tile),
                "materialized" => GraphExecutor::new(def.threads).materialized(),
                // the tracing-overhead contender: identical to `fused`
                // except every run records spans into a live ring
                "traced" => GraphExecutor::new(def.threads)
                    .with_tile_cols(def.tile)
                    .with_trace(TraceRing::new(4096)),
                _ => GraphExecutor::new(def.threads).with_tile_cols(def.tile),
            };
            let (c, h, w) = prepared.input_shape();
            let batch = def.batch;
            let input: Vec<f32> =
                (0..batch * c * h * w).map(|i| ((i % 19) as f32) * 0.21 - 1.9).collect();
            Ok(Box::new(move || {
                exec.run(prepared.net(), &input, batch).expect("infer run")
            }))
        }
        Workload::Serve { model, dataset, requests, max_batch, max_wait_ms } => {
            let prepared = prepared_for(model, dataset, "rule", def.seed)?;
            let n = prepared.input_len();
            let coalesced = def.engine == "coalesced";
            let (mb, mw) = if coalesced {
                (*max_batch, Duration::from_secs_f64(max_wait_ms / 1e3))
            } else {
                (1, Duration::ZERO)
            };
            let session = Session::builder(prepared)
                .threads(def.threads)
                .max_batch(mb)
                .max_wait(mw)
                .build();
            let nreq = *requests;
            let mk = move |tag: usize| -> Vec<f32> {
                (0..n).map(|j| (((tag * 31 + j) % 17) as f32) * 0.25 - 2.0).collect()
            };
            Ok(Box::new(move || {
                let mut out = Vec::new();
                if coalesced {
                    let tickets: Vec<_> =
                        (0..nreq).map(|tag| session.submit(mk(tag)).expect("submit")).collect();
                    for t in tickets {
                        out.extend(t.wait().expect("serve wait"));
                    }
                } else {
                    for tag in 0..nreq {
                        out.extend(session.infer(mk(tag)).expect("serve infer"));
                    }
                }
                out
            }))
        }
        Workload::Routed { models, requests, max_batch, max_wait_ms } => {
            let routed = def.engine == "routed";
            let wait = Duration::from_secs_f64(max_wait_ms / 1e3);
            let prepared: Vec<(String, PreparedModel)> = models
                .iter()
                .map(|name| Ok((name.clone(), prepared_for(name, "cifar10", "rule", def.seed)?)))
                .collect::<Result<_>>()?;
            // one deterministic input stream per (model, tag) pair so
            // both engines serve byte-identical request sequences
            let lens: Vec<usize> = prepared.iter().map(|(_, p)| p.input_len()).collect();
            let mk = move |m: usize, tag: usize, len: usize| -> Vec<f32> {
                (0..len).map(|j| (((tag * 31 + j + m * 97) % 17) as f32) * 0.25 - 2.0).collect()
            };
            let nreq = *requests;
            let nmodels = prepared.len();
            if routed {
                let registry = ModelRegistry::new();
                for (name, p) in &prepared {
                    registry.insert(name, p.clone());
                }
                let names: Vec<String> = prepared.iter().map(|(n, _)| n.clone()).collect();
                let server = Server::builder(registry)
                    .threads(def.threads)
                    .max_batch(*max_batch)
                    .max_wait(wait)
                    .build();
                Ok(Box::new(move || {
                    let tickets: Vec<_> = (0..nreq)
                        .map(|tag| {
                            let m = tag % nmodels;
                            let req = InferRequest::new(&names[m], mk(m, tag, lens[m]));
                            server.submit(req).expect("routed submit")
                        })
                        .collect();
                    let mut out = Vec::new();
                    for t in tickets {
                        out.extend(t.wait().expect("routed wait"));
                    }
                    out
                }))
            } else {
                let sessions: Vec<Session> = prepared
                    .iter()
                    .map(|(_, p)| {
                        Session::builder(p.clone())
                            .threads(def.threads)
                            .max_batch(*max_batch)
                            .max_wait(wait)
                            .build()
                    })
                    .collect();
                Ok(Box::new(move || {
                    let tickets: Vec<_> = (0..nreq)
                        .map(|tag| {
                            let m = tag % nmodels;
                            sessions[m].submit(mk(m, tag, lens[m])).expect("isolated submit")
                        })
                        .collect();
                    let mut out = Vec::new();
                    for t in tickets {
                        out.extend(t.wait().expect("isolated wait"));
                    }
                    out
                }))
            }
        }
    }
}

/// Run one definition in-process: warmup (checksumming the first run),
/// then `samples` timed runs.  `samples`/`warmup` override the
/// definition's counts when given (the CI reduced-iteration knob).
pub fn measure(
    def: &BenchDef,
    samples: Option<usize>,
    warmup: Option<usize>,
) -> Result<Measurement> {
    let mut run = build_runner(def)?;
    let warmup = warmup.unwrap_or(def.warmup).max(1);
    let samples = samples.unwrap_or(def.samples).max(1);
    let mut checksum = String::new();
    for i in 0..warmup {
        let out = black_box(run());
        if i == 0 {
            checksum = checksum_f32s(&out);
        }
    }
    let mut ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        black_box(run());
        ns.push(t.elapsed().as_nanos() as f64);
    }
    let mean = ns.iter().sum::<f64>() / ns.len() as f64;
    let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / ns.len() as f64;
    let min = ns.iter().cloned().fold(f64::INFINITY, f64::min);
    Ok(Measurement {
        name: def.name.clone(),
        engine: def.engine.clone(),
        config: def.config_json(),
        iters: samples,
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        min_ns: min,
        checksum,
        rev: git_rev(),
    })
}

/// Run one definition in a **child process** (re-exec the current
/// binary with `bench --child`) so nothing leaks between measurements.
/// The child prints `RECORD {json}` on stdout; everything else it says
/// is passed through.
pub fn measure_in_child(
    def: &BenchDef,
    samples: Option<usize>,
    warmup: Option<usize>,
) -> Result<Measurement> {
    let source = def
        .source
        .as_ref()
        .ok_or_else(|| anyhow!("definition '{}' has no source file to re-load", def.id()))?;
    let exe = std::env::current_exe().context("locate current executable")?;
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("bench").arg("--defs").arg(source).arg("--only").arg(def.id());
    if let Some(s) = samples {
        cmd.arg("--samples").arg(s.to_string());
    }
    if let Some(w) = warmup {
        cmd.arg("--warmup").arg(w.to_string());
    }
    cmd.arg("--child");
    let out = cmd.output().with_context(|| format!("spawn child for '{}'", def.id()))?;
    let stdout = String::from_utf8_lossy(&out.stdout);
    if !out.status.success() {
        bail!(
            "child measurement of '{}' failed ({}):\n{}{}",
            def.id(),
            out.status,
            stdout,
            String::from_utf8_lossy(&out.stderr)
        );
    }
    let line = stdout
        .lines()
        .find_map(|l| l.strip_prefix("RECORD "))
        .ok_or_else(|| anyhow!("child for '{}' printed no RECORD line:\n{stdout}", def.id()))?;
    Measurement::from_json(&crate::util::json::Value::parse(line)?)
        .with_context(|| format!("parse child record for '{}'", def.id()))
}

/// One definition's `--check` verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckOutcome {
    /// Observed checksum equals the pinned one.
    Matched,
    /// Observed checksum differs — the benchmark's output is wrong (or
    /// the pin is stale).  Always a failure.
    Mismatched { expected: String, actual: String },
    /// The definition has no pinned checksum yet; a failure only under
    /// `--strict`.
    Unpinned { actual: String },
}

/// `--check` over a definition set.
#[derive(Debug, Clone)]
pub struct CheckReport {
    /// `(benchmark id, source file, outcome)` per definition, in input
    /// order.
    pub rows: Vec<(String, Option<std::path::PathBuf>, CheckOutcome)>,
}

impl CheckReport {
    pub fn mismatched(&self) -> usize {
        self.rows.iter().filter(|(_, _, o)| matches!(o, CheckOutcome::Mismatched { .. })).count()
    }

    pub fn unpinned(&self) -> usize {
        self.rows.iter().filter(|(_, _, o)| matches!(o, CheckOutcome::Unpinned { .. })).count()
    }

    /// Nonzero-exit decision: mismatches always fail; unpinned
    /// definitions fail only under `strict`.
    pub fn failed(&self, strict: bool) -> bool {
        self.mismatched() > 0 || (strict && self.unpinned() > 0)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (id, _, outcome) in &self.rows {
            match outcome {
                CheckOutcome::Matched => out.push_str(&format!("ok        {id}\n")),
                CheckOutcome::Unpinned { actual } => {
                    out.push_str(&format!("unpinned  {id} (observed {actual})\n"))
                }
                CheckOutcome::Mismatched { expected, actual } => out.push_str(&format!(
                    "MISMATCH  {id}: pinned {expected}, observed {actual}\n"
                )),
            }
        }
        out.push_str(&format!(
            "{} checked, {} mismatched, {} unpinned\n",
            self.rows.len(),
            self.mismatched(),
            self.unpinned()
        ));
        out
    }
}

/// Run every definition **once** (no timing) and compare observed
/// output checksums against the pinned ones.
pub fn check_defs(defs: &[BenchDef]) -> Result<CheckReport> {
    let mut rows = Vec::new();
    for def in defs {
        let mut run = build_runner(def)?;
        let actual = checksum_f32s(&run());
        let outcome = match &def.checksum {
            None => CheckOutcome::Unpinned { actual },
            Some(expected) if *expected == actual => CheckOutcome::Matched,
            Some(expected) => {
                CheckOutcome::Mismatched { expected: expected.clone(), actual }
            }
        };
        rows.push((def.id(), def.source.clone(), outcome));
    }
    Ok(CheckReport { rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::defs::defs_from_str;

    const TINY: &str = r#"{
      "format": "prunemap.benchdefs.v1",
      "benchmarks": [
        {"name": "spmm/tiny", "engine": "scalar", "kind": "spmm",
         "rows": 64, "cols": 64, "scheme": "block4x4", "compression": 4.0,
         "batch": 4, "samples": 2},
        {"name": "spmm/tiny", "engine": "simd", "kind": "spmm",
         "rows": 64, "cols": 64, "scheme": "block4x4", "compression": 4.0,
         "batch": 4, "samples": 2}
      ]
    }"#;

    #[test]
    fn measure_times_a_tiny_spmm_def() {
        let defs = defs_from_str(TINY).unwrap();
        let m = measure(&defs[0], Some(3), Some(1)).unwrap();
        assert_eq!(m.id(), "spmm/tiny::scalar");
        assert_eq!(m.iters, 3);
        assert!(m.mean_ns > 0.0 && m.min_ns > 0.0);
        assert!(m.min_ns <= m.mean_ns);
        assert_eq!(m.checksum.len(), 16);
        // the record round-trips through its own JSON
        let back = Measurement::from_json(&m.to_json()).unwrap();
        assert_eq!(back.checksum, m.checksum);
    }

    #[test]
    fn engine_variants_of_one_workload_share_a_checksum() {
        // the barometer's core correctness premise: scalar and SIMD
        // paths are bit-identical, so one pinned checksum covers both
        let defs = defs_from_str(TINY).unwrap();
        let scalar = measure(&defs[0], Some(1), Some(1)).unwrap();
        let simd = measure(&defs[1], Some(1), Some(1)).unwrap();
        assert_eq!(scalar.checksum, simd.checksum, "scalar vs simd outputs diverged");
    }

    #[test]
    fn check_flags_a_wrong_pin_and_reports_unpinned() {
        let mut defs = defs_from_str(TINY).unwrap();
        defs[0].checksum = Some("0000000000000000".to_string()); // wrong on purpose
        let report = check_defs(&defs).unwrap();
        assert_eq!(report.mismatched(), 1);
        assert_eq!(report.unpinned(), 1);
        assert!(report.failed(false), "a mismatch fails even without --strict");
        assert!(matches!(
            &report.rows[0].2,
            CheckOutcome::Mismatched { expected, .. } if expected == "0000000000000000"
        ));
        // pin the observed value -> clean strict pass
        let CheckOutcome::Mismatched { actual, .. } = report.rows[0].2.clone() else {
            unreachable!()
        };
        let CheckOutcome::Unpinned { actual: actual1 } = report.rows[1].2.clone() else {
            unreachable!()
        };
        defs[0].checksum = Some(actual);
        defs[1].checksum = Some(actual1);
        let clean = check_defs(&defs).unwrap();
        assert!(!clean.failed(true));
        assert_eq!(clean.mismatched() + clean.unpinned(), 0);
    }

    #[test]
    fn checksums_are_deterministic_across_measure_calls() {
        let defs = defs_from_str(TINY).unwrap();
        let a = measure(&defs[1], Some(1), Some(2)).unwrap();
        let b = measure(&defs[1], Some(1), Some(1)).unwrap();
        assert_eq!(a.checksum, b.checksum);
    }
}
