//! Benchmark definitions as data.
//!
//! A definition file is JSON (parsed with [`crate::util::json`] — the
//! offline environment has no TOML parser) of the form:
//!
//! ```json
//! {
//!   "format": "prunemap.benchdefs.v1",
//!   "benchmarks": [
//!     {
//!       "name": "spmm/block1024/b32",
//!       "engine": "simd",
//!       "kind": "spmm",
//!       "rows": 1024, "cols": 1024,
//!       "scheme": "block8x8", "compression": 10.0,
//!       "batch": 32, "threads": 1, "seed": 1,
//!       "warmup": 1, "samples": 10,
//!       "checksum": "9c0f..."
//!     }
//!   ]
//! }
//! ```
//!
//! `name` identifies the *workload*; `engine` names the variant under
//! measurement (`scalar` vs `simd`, `materialized` vs `fused`, ...), so
//! the [`cmp`](super::cmp) reporter can pair records across record sets
//! by the full id `name::engine` and [`rank`](super::cmp::rank) can
//! order variants of one workload within a record set.  `checksum` is
//! the expected output checksum ([`super::checksum_f32s`]); it is
//! optional while a definition is being authored and pinned by
//! `prunemap bench --check --update-checksums` on a machine with a
//! toolchain (unpinned definitions fail `--check --strict`).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::pruning::Scheme;
use crate::sparse::DEFAULT_TILE_COLS;
use crate::util::json::Value;

/// Definition-file format tag.
pub const FORMAT: &str = "prunemap.benchdefs.v1";

/// One benchmark definition: a workload × engine variant plus the
/// measurement protocol (warmup/samples) and the expected checksum.
#[derive(Debug, Clone)]
pub struct BenchDef {
    /// Workload id, e.g. `"spmm/block1024/b32"`.
    pub name: String,
    /// Engine variant under measurement, e.g. `"simd"`.
    pub engine: String,
    /// What to run (and its workload-specific parameters).
    pub workload: Workload,
    /// Engine worker threads (1 = serial dispatch).
    pub threads: usize,
    /// Batch width (samples per run for spmm/conv/infer).
    pub batch: usize,
    /// Fused-im2col tile width.
    pub tile: usize,
    /// Untimed runs before sampling (>= 1: the first run also computes
    /// the output checksum).
    pub warmup: usize,
    /// Timed samples per measurement.
    pub samples: usize,
    /// Deterministic seed for weights and inputs.
    pub seed: u64,
    /// Expected output checksum; `None` until pinned.
    pub checksum: Option<String>,
    /// The definition file this came from (set by [`load_defs`]) — how
    /// the harness tells a child process which file to re-read.
    pub source: Option<PathBuf>,
}

impl BenchDef {
    /// The full benchmark id records and reporters key on.
    pub fn id(&self) -> String {
        format!("{}::{}", self.name, self.engine)
    }

    /// The engine-config echo carried into measurement records.
    pub fn config_json(&self) -> Value {
        Value::obj(vec![
            ("threads", Value::num(self.threads as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("tile", Value::num(self.tile as f64)),
            ("seed", Value::str(self.seed.to_string())),
        ])
    }
}

/// The workload families a definition can name, with their parameters.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Batched sparse GEMM on one pruned, row-reordered matrix.
    /// Engines: `scalar` (the locked reference loop) | `simd`.
    Spmm { rows: usize, cols: usize, scheme: Scheme, compression: f32 },
    /// One 3×3 SAME conv lowered through im2col.
    /// Engines: `materialized` | `fused`.
    Conv { in_ch: usize, out_ch: usize, hw: usize, scheme: Scheme, compression: f32 },
    /// Whole-network inference through the graph executor.
    /// Engines: `serial` | `fused` | `materialized` | `traced` (fused
    /// with an attached [`crate::telemetry::TraceRing`] — the overhead
    /// barometer for always-on span recording).
    Infer { model: String, dataset: String, method: String },
    /// A burst of single-sample requests through one serving session.
    /// Engines: `one_per_run` | `coalesced`.
    Serve { model: String, dataset: String, requests: usize, max_batch: usize, max_wait_ms: f64 },
    /// An interleaved burst across several models: isolated per-model
    /// sessions vs one routing front door.
    /// Engines: `isolated` | `routed`.
    Routed { models: Vec<String>, requests: usize, max_batch: usize, max_wait_ms: f64 },
}

impl Workload {
    /// Engine variants this workload accepts.
    pub fn engines(&self) -> &'static [&'static str] {
        match self {
            Workload::Spmm { .. } => &["scalar", "simd"],
            Workload::Conv { .. } => &["materialized", "fused"],
            Workload::Infer { .. } => &["serial", "fused", "materialized", "traced"],
            Workload::Serve { .. } => &["one_per_run", "coalesced"],
            Workload::Routed { .. } => &["isolated", "routed"],
        }
    }
}

/// Parse a compact scheme name: `dense` (no pruning), `unstructured`,
/// `pattern`, `blockPxQ` (FC block pruning, e.g. `block8x8`), or
/// `punchedFxC` (conv block-punched, e.g. `punched8x16`).
pub fn parse_scheme(s: &str) -> Result<Scheme> {
    fn pair(body: &str, what: &str) -> Result<(usize, usize)> {
        let (a, b) = body
            .split_once('x')
            .ok_or_else(|| anyhow!("{what} scheme needs 'AxB' sizes, got '{body}'"))?;
        Ok((
            a.parse().map_err(|_| anyhow!("bad {what} size '{a}'"))?,
            b.parse().map_err(|_| anyhow!("bad {what} size '{b}'"))?,
        ))
    }
    match s {
        "dense" | "none" => Ok(Scheme::None),
        "unstructured" => Ok(Scheme::Unstructured),
        "pattern" => Ok(Scheme::Pattern),
        _ => {
            if let Some(body) = s.strip_prefix("block") {
                let (bp, bq) = pair(body, "block")?;
                Ok(Scheme::Block { bp, bq })
            } else if let Some(body) = s.strip_prefix("punched") {
                let (bf, bc) = pair(body, "punched")?;
                Ok(Scheme::BlockPunched { bf, bc })
            } else {
                bail!("unknown scheme '{s}' (dense|unstructured|pattern|blockPxQ|punchedFxC)")
            }
        }
    }
}

fn opt_usize(v: &Value, key: &str, default: usize) -> Result<usize> {
    match v.opt(key) {
        Some(x) => x.as_usize().with_context(|| format!("field '{key}'")),
        None => Ok(default),
    }
}

fn opt_f64(v: &Value, key: &str, default: f64) -> Result<f64> {
    match v.opt(key) {
        Some(x) => x.as_f64().with_context(|| format!("field '{key}'")),
        None => Ok(default),
    }
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    Ok(v.get(key)?.as_str().with_context(|| format!("field '{key}'"))?.to_string())
}

fn opt_str(v: &Value, key: &str, default: &str) -> Result<String> {
    match v.opt(key) {
        Some(x) => Ok(x.as_str().with_context(|| format!("field '{key}'"))?.to_string()),
        None => Ok(default.to_string()),
    }
}

/// Parse one benchmark definition object.
pub fn def_from_json(v: &Value) -> Result<BenchDef> {
    let name = req_str(v, "name")?;
    let engine = req_str(v, "engine")?;
    let kind = req_str(v, "kind")?;
    let workload = match kind.as_str() {
        "spmm" => {
            let scheme = parse_scheme(&opt_str(v, "scheme", "block8x8")?)?;
            if !matches!(scheme, Scheme::None | Scheme::Unstructured | Scheme::Block { .. }) {
                bail!("spmm workloads prune a 2-D matrix: scheme must be dense|unstructured|blockPxQ");
            }
            Workload::Spmm {
                rows: opt_usize(v, "rows", 1024)?,
                cols: opt_usize(v, "cols", 1024)?,
                scheme,
                compression: opt_f64(v, "compression", 8.0)? as f32,
            }
        }
        "conv" => {
            let scheme = parse_scheme(&opt_str(v, "scheme", "punched8x16")?)?;
            if !matches!(scheme, Scheme::BlockPunched { .. } | Scheme::Pattern) {
                bail!("conv workloads prune a 4-D kernel: scheme must be punchedFxC|pattern");
            }
            Workload::Conv {
                in_ch: opt_usize(v, "in_ch", 128)?,
                out_ch: opt_usize(v, "out_ch", 128)?,
                hw: opt_usize(v, "hw", 32)?,
                scheme,
                compression: opt_f64(v, "compression", 8.0)? as f32,
            }
        }
        "infer" => Workload::Infer {
            model: req_str(v, "model")?,
            dataset: opt_str(v, "dataset", "cifar10")?,
            method: opt_str(v, "method", "rule")?,
        },
        "serve" => Workload::Serve {
            model: req_str(v, "model")?,
            dataset: opt_str(v, "dataset", "cifar10")?,
            requests: opt_usize(v, "requests", 48)?,
            max_batch: opt_usize(v, "max_batch", 32)?,
            max_wait_ms: opt_f64(v, "max_wait_ms", 5.0)?,
        },
        "routed" => {
            let models = v.get("models")?.str_vec().context("field 'models'")?;
            if models.len() < 2 {
                bail!("routed workloads need >= 2 models, got {models:?}");
            }
            Workload::Routed {
                models,
                requests: opt_usize(v, "requests", 48)?,
                max_batch: opt_usize(v, "max_batch", 16)?,
                max_wait_ms: opt_f64(v, "max_wait_ms", 5.0)?,
            }
        }
        other => bail!("unknown workload kind '{other}' (spmm|conv|infer|serve|routed)"),
    };
    if !workload.engines().contains(&engine.as_str()) {
        bail!(
            "benchmark '{name}': engine '{engine}' is not a {kind} variant (expected one of {:?})",
            workload.engines()
        );
    }
    let checksum = match v.opt("checksum") {
        Some(Value::Null) | None => None,
        Some(x) => Some(x.as_str().context("field 'checksum'")?.to_string()),
    };
    let def = BenchDef {
        name,
        engine,
        workload,
        threads: opt_usize(v, "threads", 1)?,
        batch: opt_usize(v, "batch", 1)?,
        tile: opt_usize(v, "tile", DEFAULT_TILE_COLS)?,
        warmup: opt_usize(v, "warmup", 1)?.max(1),
        samples: opt_usize(v, "samples", 10)?.max(1),
        seed: match v.opt("seed") {
            Some(x) => x.as_u64().context("field 'seed'")?,
            None => 1,
        },
        checksum,
        source: None,
    };
    Ok(def)
}

/// Parse a whole definition file's text.
pub fn defs_from_str(text: &str) -> Result<Vec<BenchDef>> {
    let v = Value::parse(text)?;
    let format = v.get("format")?.as_str()?;
    if format != FORMAT {
        bail!("unsupported definition format '{format}' (expected '{FORMAT}')");
    }
    v.get("benchmarks")?
        .as_arr()?
        .iter()
        .map(def_from_json)
        .collect()
}

/// Load definitions from one `.json` file, or every `*.json` file
/// (sorted by name) in a directory.  Ids must be unique across the set.
pub fn load_defs(path: impl AsRef<Path>) -> Result<Vec<BenchDef>> {
    let path = path.as_ref();
    let files: Vec<PathBuf> = if path.is_dir() {
        let mut files: Vec<PathBuf> = std::fs::read_dir(path)
            .with_context(|| format!("read definition dir {}", path.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|e| e == "json"))
            .collect();
        files.sort();
        files
    } else {
        vec![path.to_path_buf()]
    };
    if files.is_empty() {
        bail!("no .json definition files under {}", path.display());
    }
    let mut defs = Vec::new();
    for file in files {
        let text = std::fs::read_to_string(&file)
            .with_context(|| format!("read definitions from {}", file.display()))?;
        let mut file_defs = defs_from_str(&text)
            .with_context(|| format!("parse definitions in {}", file.display()))?;
        for def in &mut file_defs {
            def.source = Some(file.clone());
        }
        defs.append(&mut file_defs);
    }
    let mut seen = BTreeSet::new();
    for def in &defs {
        if !seen.insert(def.id()) {
            bail!("duplicate benchmark id '{}'", def.id());
        }
    }
    Ok(defs)
}

/// Write `checksum` into the definition named by `id` inside its source
/// file (the `--update-checksums` pinning flow).  Returns whether the
/// file changed.
pub fn pin_checksum(file: &Path, id: &str, checksum: &str) -> Result<bool> {
    let text = std::fs::read_to_string(file)
        .with_context(|| format!("read definitions from {}", file.display()))?;
    let mut v = Value::parse(&text)?;
    let mut changed = false;
    if let Value::Obj(top) = &mut v {
        if let Some(Value::Arr(benchmarks)) = top.get_mut("benchmarks") {
            for b in benchmarks {
                let matches_id = match (b.opt("name"), b.opt("engine")) {
                    (Some(Value::Str(n)), Some(Value::Str(e))) => format!("{n}::{e}") == id,
                    _ => false,
                };
                if !matches_id {
                    continue;
                }
                let prev = b.opt("checksum").cloned();
                if let Value::Obj(obj) = b {
                    obj.insert("checksum".to_string(), Value::str(checksum));
                }
                changed |= prev != Some(Value::str(checksum));
            }
        }
    }
    if changed {
        std::fs::write(file, v.pretty())
            .with_context(|| format!("rewrite definitions in {}", file.display()))?;
    }
    Ok(changed)
}

#[cfg(test)]
mod tests {
    use super::*;

    const ONE: &str = r#"{
      "format": "prunemap.benchdefs.v1",
      "benchmarks": [
        {"name": "spmm/tiny/b8", "engine": "simd", "kind": "spmm",
         "rows": 64, "cols": 64, "scheme": "block4x4", "compression": 4.0,
         "batch": 8, "samples": 3, "checksum": "abc"}
      ]
    }"#;

    #[test]
    fn parses_a_minimal_file() {
        let defs = defs_from_str(ONE).unwrap();
        assert_eq!(defs.len(), 1);
        let d = &defs[0];
        assert_eq!(d.id(), "spmm/tiny/b8::simd");
        assert_eq!((d.batch, d.samples, d.warmup, d.threads), (8, 3, 1, 1));
        assert_eq!(d.checksum.as_deref(), Some("abc"));
        match &d.workload {
            Workload::Spmm { rows, cols, scheme, compression } => {
                assert_eq!((*rows, *cols), (64, 64));
                assert_eq!(*scheme, Scheme::Block { bp: 4, bq: 4 });
                assert_eq!(*compression, 4.0);
            }
            other => panic!("expected spmm, got {other:?}"),
        }
    }

    #[test]
    fn scheme_names_parse() {
        assert_eq!(parse_scheme("dense").unwrap(), Scheme::None);
        assert_eq!(parse_scheme("unstructured").unwrap(), Scheme::Unstructured);
        assert_eq!(parse_scheme("pattern").unwrap(), Scheme::Pattern);
        assert_eq!(parse_scheme("block8x16").unwrap(), Scheme::Block { bp: 8, bq: 16 });
        assert_eq!(
            parse_scheme("punched8x16").unwrap(),
            Scheme::BlockPunched { bf: 8, bc: 16 }
        );
        assert!(parse_scheme("blocky").is_err());
        assert!(parse_scheme("block8").is_err());
        assert!(parse_scheme("magic").is_err());
    }

    #[test]
    fn rejects_bad_definitions() {
        // wrong format tag
        assert!(defs_from_str(r#"{"format": "v0", "benchmarks": []}"#).is_err());
        // engine not a variant of the kind
        let bad_engine = ONE.replace("\"simd\"", "\"fused\"");
        assert!(defs_from_str(&bad_engine).is_err());
        // conv cannot take an FC block scheme
        let mixed = r#"{
          "format": "prunemap.benchdefs.v1",
          "benchmarks": [
            {"name": "x", "engine": "fused", "kind": "conv", "scheme": "block8x8"}
          ]
        }"#;
        assert!(defs_from_str(mixed).is_err());
        // routed needs two models
        let routed = r#"{
          "format": "prunemap.benchdefs.v1",
          "benchmarks": [
            {"name": "x", "engine": "routed", "kind": "routed", "models": ["a"]}
          ]
        }"#;
        assert!(defs_from_str(routed).is_err());
    }

    #[test]
    fn checked_in_definition_files_stay_valid() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/benches/defs");
        let defs = load_defs(dir).expect("checked-in defs must parse");
        assert!(defs.len() >= 8, "expected the ported hotpaths set, got {}", defs.len());
        for def in &defs {
            assert!(def.source.is_some());
        }
    }

    #[test]
    fn pin_checksum_rewrites_the_file() {
        let path = std::env::temp_dir().join(format!(
            "prunemap_pin_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::write(&path, ONE).unwrap();
        assert!(pin_checksum(&path, "spmm/tiny/b8::simd", "0123456789abcdef").unwrap());
        let defs = load_defs(&path).unwrap();
        assert_eq!(defs[0].checksum.as_deref(), Some("0123456789abcdef"));
        // idempotent: same value -> no change
        assert!(!pin_checksum(&path, "spmm/tiny/b8::simd", "0123456789abcdef").unwrap());
        // unknown id -> untouched
        assert!(!pin_checksum(&path, "nope::simd", "ffff").unwrap());
        let _ = std::fs::remove_file(&path);
    }
}
