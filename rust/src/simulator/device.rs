//! Mobile-SoC device profiles (the stand-ins for the paper's test phones).
//!
//! Numbers are derived from public specs of the Snapdragon 855/865/888
//! mobile GPUs (Adreno 640/650/660): peak FP16 MAC throughput, effective
//! LPDDR4X/5 bandwidth, SIMD lane width, and an empirical per-kernel
//! dispatch overhead.  Absolute values only anchor the scale; the mapping
//! methods depend on *relative* orderings, which come from the cost model.

/// One target device.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Peak MACs/second (FP16) of the mobile GPU.
    pub peak_macs: f64,
    /// Effective memory bandwidth, bytes/second.
    pub mem_bw: f64,
    /// SIMD lane width the generated code vectorizes over.
    pub simd_lanes: usize,
    /// Concurrent thread groups (waves) the GPU sustains.
    pub threads: usize,
    /// Fixed per-kernel-launch overhead, milliseconds.
    pub dispatch_ms: f64,
    /// Last-level cache, bytes (tiling target).
    pub l2_bytes: usize,
    /// Work (output elems x filters) needed to saturate the GPU; the
    /// utilization knee — smaller layers can't fill the machine.
    pub saturation_work: f64,
}

impl DeviceProfile {
    /// Samsung Galaxy S10 — Snapdragon 855, Adreno 640 (the paper's main
    /// evaluation device).
    pub fn s10() -> Self {
        DeviceProfile {
            name: "Galaxy S10 (Adreno 640)",
            peak_macs: 450e9,
            mem_bw: 34e9,
            simd_lanes: 64,
            threads: 8,
            dispatch_ms: 0.030,
            l2_bytes: 1 << 20,
            saturation_work: 5.0e5,
        }
    }

    /// Samsung Galaxy S20 — Snapdragon 865, Adreno 650.
    pub fn s20() -> Self {
        DeviceProfile {
            name: "Galaxy S20 (Adreno 650)",
            peak_macs: 600e9,
            mem_bw: 44e9,
            simd_lanes: 64,
            threads: 8,
            dispatch_ms: 0.027,
            l2_bytes: (1 << 20) + (1 << 19),
            saturation_work: 5.5e5,
        }
    }

    /// Samsung Galaxy S21 — Snapdragon 888, Adreno 660.
    pub fn s21() -> Self {
        DeviceProfile {
            name: "Galaxy S21 (Adreno 660)",
            peak_macs: 740e9,
            mem_bw: 51e9,
            simd_lanes: 64,
            threads: 8,
            dispatch_ms: 0.024,
            l2_bytes: 1 << 21,
            saturation_work: 6.0e5,
        }
    }

    /// Lookup by short name ("s10" | "s20" | "s21").
    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        match name.to_ascii_lowercase().as_str() {
            "s10" => Some(Self::s10()),
            "s20" => Some(Self::s20()),
            "s21" => Some(Self::s21()),
            _ => None,
        }
    }

    pub fn all() -> Vec<DeviceProfile> {
        vec![Self::s10(), Self::s20(), Self::s21()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generations_get_faster() {
        let (a, b, c) = (DeviceProfile::s10(), DeviceProfile::s20(), DeviceProfile::s21());
        assert!(a.peak_macs < b.peak_macs && b.peak_macs < c.peak_macs);
        assert!(a.mem_bw < b.mem_bw && b.mem_bw < c.mem_bw);
    }

    #[test]
    fn lookup() {
        assert!(DeviceProfile::by_name("S10").is_some());
        assert!(DeviceProfile::by_name("s21").is_some());
        assert!(DeviceProfile::by_name("pixel").is_none());
        assert_eq!(DeviceProfile::all().len(), 3);
    }
}
