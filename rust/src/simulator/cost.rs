//! Analytic execution-cost model for pruned DNN layers on a mobile GPU.
//!
//! This is the substitution for the paper's on-device measurements (see
//! DESIGN.md §2).  The model reproduces the *mechanisms* the paper reports,
//! so relative orderings — which drive both mapping methods — match:
//!
//! * roofline: `latency = dispatch + max(compute, memory)` with partial
//!   overlap;
//! * **utilization saturates with block size** (Fig. 9): the SIMD-parallel
//!   work unit of block-punched/block-based execution is the surviving
//!   block; small blocks starve the lanes, large blocks approach dense
//!   throughput;
//! * **weight-reuse collapse on small feature maps** (Fig. 9): at
//!   iso-MACs, fewer output positions mean less parallel work per weight
//!   (`u_size`) and more weight traffic per MAC;
//! * **irregularity costs** (Fig. 5): unstructured sparsity pays per-nnz
//!   index arithmetic, gather traffic, and thread-divergence penalties
//!   (reduced, not removed, by row reordering);
//! * **pattern-based pruning** enjoys SIMD-fit 4-entry kernels with a small
//!   per-pattern branch cost that *grows with kernel size* — the reason the
//!   paper confines patterns to 3x3 (§2.1.1);
//! * per-kernel dispatch overhead, reduced by layer fusion.

use crate::models::{LayerKind, LayerSpec};
use crate::pruning::Scheme;

use super::device::DeviceProfile;

/// Tile parameters chosen by the auto-tuner (App. A.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileParams {
    /// Output-row tile (filters per workgroup).
    pub tile_m: usize,
    /// Output-column tile (spatial positions per workgroup).
    pub tile_n: usize,
    /// Inner-loop unroll factor.
    pub unroll: usize,
}

impl TileParams {
    /// A sane untuned default.
    pub fn default_for(dev: &DeviceProfile) -> TileParams {
        TileParams { tile_m: 8, tile_n: dev.simd_lanes, unroll: 4 }
    }

    /// The search grid the GA tuner explores.
    pub fn candidates() -> Vec<TileParams> {
        let mut out = Vec::new();
        for &tile_m in &[4usize, 8, 16, 32] {
            for &tile_n in &[16usize, 32, 64, 128, 256] {
                for &unroll in &[1usize, 2, 4, 8] {
                    out.push(TileParams { tile_m, tile_n, unroll });
                }
            }
        }
        out
    }
}

/// Full execution configuration for one layer.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    pub scheme: Scheme,
    /// Parameter compression rate (>= 1.0; 1.0 = dense).
    pub compression: f32,
    pub tile: TileParams,
    /// Layer fusion applied (conv+bn+relu in one kernel).
    pub fused: bool,
    /// Row reordering applied (load balance for irregular sparsity).
    pub reordered: bool,
}

impl ExecConfig {
    pub fn dense(dev: &DeviceProfile) -> ExecConfig {
        ExecConfig {
            scheme: Scheme::None,
            compression: 1.0,
            tile: TileParams::default_for(dev),
            fused: true,
            reordered: true,
        }
    }

    pub fn new(scheme: Scheme, compression: f32, dev: &DeviceProfile) -> ExecConfig {
        ExecConfig {
            scheme,
            compression: compression.max(1.0),
            tile: TileParams::default_for(dev),
            fused: true,
            reordered: true,
        }
    }
}

/// Scheme-dependent execution factors.
struct SchemeFactors {
    /// Peak-utilization ceiling for this regularity.
    u_scheme: f64,
    /// Extra compute ops per retained MAC (index math, branches).
    extra_ops_per_mac: f64,
    /// Index bytes per retained weight.
    index_bytes_per_w: f64,
    /// Thread-divergence multiplier (>= 1).
    divergence: f64,
}

fn scheme_factors(
    layer: &LayerSpec,
    scheme: &Scheme,
    dev: &DeviceProfile,
    reordered: bool,
) -> SchemeFactors {
    let lanes = dev.simd_lanes as f64;
    match scheme {
        Scheme::None | Scheme::StructuredRow | Scheme::StructuredColumn => SchemeFactors {
            u_scheme: 1.0,
            extra_ops_per_mac: 0.0,
            index_bytes_per_w: 0.0,
            divergence: 1.0,
        },
        Scheme::Unstructured => SchemeFactors {
            // gather-per-element; CSR index arithmetic roughly doubles the
            // inner-loop op count and defeats vectorization
            u_scheme: 0.30,
            extra_ops_per_mac: 1.0,
            index_bytes_per_w: 4.0,
            divergence: if reordered { 1.10 } else { 1.30 },
        },
        Scheme::Pattern => {
            // 4-entry patterns match SIMD registers; branch cost grows with
            // the pattern candidate space, i.e. with kernel area (the paper:
            // 8-16 pattern types are cheap for 3x3, prohibitive for 5x5+)
            let area = (layer.kh * layer.kw) as f64;
            let branch = 0.04 * (area / 9.0);
            SchemeFactors {
                u_scheme: 0.80,
                extra_ops_per_mac: 0.10 + branch,
                index_bytes_per_w: 0.5, // pattern id per kernel + kernel idx
                divergence: if reordered { 1.03 } else { 1.12 },
            }
        }
        Scheme::Block { bp, bq } => block_factors((bp * bq) as f64, lanes, reordered),
        Scheme::BlockPunched { bf, bc } => block_factors((bf * bc) as f64, lanes, reordered),
    }
}

/// Shared saturation curve for block-based/block-punched execution: the
/// SIMD-parallel unit is the (surviving) block; utilization approaches the
/// dense ceiling as the block grows past the lane width.
fn block_factors(block_elems: f64, lanes: f64, reordered: bool) -> SchemeFactors {
    let u = 0.97 * block_elems / (block_elems + lanes);
    SchemeFactors {
        u_scheme: u.max(0.05),
        // one BCS column-list fetch amortized over the block
        extra_ops_per_mac: 0.02 + 2.0 / block_elems.max(1.0),
        index_bytes_per_w: 8.0 / block_elems.max(1.0).sqrt(),
        divergence: if reordered { 1.02 } else { 1.08 },
    }
}

/// Latency of one layer under `cfg` on `dev`, in milliseconds (batch 1).
pub fn layer_latency_ms(layer: &LayerSpec, cfg: &ExecConfig, dev: &DeviceProfile) -> f64 {
    let keep = 1.0 / cfg.compression.max(1.0) as f64;
    let total_w = layer.params() as f64;
    let kept_w = (total_w * keep).max(1.0);
    let out_hw = layer.out_hw() as f64;
    let out_positions = match layer.kind {
        LayerKind::Fc => 1.0,
        _ => out_hw * out_hw,
    };
    let macs = kept_w * out_positions;

    let f = scheme_factors(layer, &cfg.scheme, dev, cfg.reordered);

    // --- utilization ---------------------------------------------------
    // machine-filling: output positions x filters is the parallel iteration
    // space; small layers can't fill the GPU
    let work = out_positions * layer.out_ch as f64;
    let u_size = work / (work + dev.saturation_work);
    let u_tile = tile_efficiency(layer, &cfg.tile, dev);
    let util = (f.u_scheme * u_size * u_tile).max(1e-3);

    // --- compute time ----------------------------------------------------
    let ops = macs * (1.0 + f.extra_ops_per_mac);
    let t_compute = ops / (dev.peak_macs * util) * 1e3;

    // --- memory time -----------------------------------------------------
    let in_hw = layer.in_hw as f64;
    let input_bytes = match layer.kind {
        LayerKind::Fc => layer.in_ch as f64 * 4.0,
        _ => layer.in_ch as f64 * in_hw * in_hw * 4.0,
    };
    let output_bytes = layer.out_ch as f64 * out_positions * 4.0;
    let weight_bytes = kept_w * 4.0 + kept_w * f.index_bytes_per_w;
    let traffic = weight_bytes + input_bytes + output_bytes;
    let t_mem = traffic / dev.mem_bw * 1e3;

    // --- dispatch --------------------------------------------------------
    // unfused: conv + bn + relu are separate kernel launches, and the
    // intermediate tensor round-trips through memory
    let (dispatch, mem_mult) = if cfg.fused {
        (dev.dispatch_ms, 1.0)
    } else {
        (dev.dispatch_ms * 2.6, 1.0 + 2.0 * output_bytes / traffic)
    };

    let t_mem = t_mem * mem_mult;
    let overlap = 0.15 * t_compute.min(t_mem);
    dispatch + (t_compute.max(t_mem) + overlap) * f.divergence
}

/// Tile efficiency: penalties for lane-misaligned tiles, cache-overflowing
/// footprints, and unroll factors outside the sweet spot.  The GA tuner
/// (compiler::tuning) searches this surface.
fn tile_efficiency(layer: &LayerSpec, tile: &TileParams, dev: &DeviceProfile) -> f64 {
    let mut eff = 1.0;
    if tile.tile_n % dev.simd_lanes != 0 {
        eff *= 0.80;
    }
    let (rows, _cols) = layer.gemm_dims();
    // footprint: weight tile + input tile + accumulators (f32)
    let footprint = (tile.tile_m * tile.tile_n + tile.tile_n * rows.min(256) + tile.tile_m * 8) * 4;
    if footprint > dev.l2_bytes {
        eff *= 0.70;
    }
    match tile.unroll {
        4 | 8 => {}
        2 => eff *= 0.96,
        1 => eff *= 0.90,
        _ => eff *= 0.93,
    }
    // degenerate tiles larger than the layer waste lanes
    if tile.tile_m > layer.out_ch {
        eff *= 0.85;
    }
    eff
}

/// Whole-model latency: sum of per-layer latencies (the runtime executes
/// layers sequentially on the mobile GPU, as the paper's framework does).
pub fn model_latency_ms(
    layers: &[LayerSpec],
    cfgs: &[ExecConfig],
    dev: &DeviceProfile,
) -> f64 {
    assert_eq!(layers.len(), cfgs.len());
    layers
        .iter()
        .zip(cfgs)
        .map(|(l, c)| layer_latency_ms(l, c, dev))
        .sum()
}

// ---------------------------------------------------------------------
// Measured-vs-modeled hooks
// ---------------------------------------------------------------------

use crate::sparse::{Bcs, Csr, DenseKernel, Engine, SparseKernel};
use crate::tensor::Tensor;

/// Outcome of running a layer's masked GEMM view on the real sparse
/// execution engine next to the analytic model's prediction.
#[derive(Debug, Clone, Copy)]
pub struct LatencyComparison {
    /// Mobile-device latency the cost model predicts (batch 1), ms.
    pub modeled_ms: f64,
    /// Host wall-clock of the engine over the same weights, ms (min over
    /// reps, whole batch).
    pub measured_ms: f64,
    pub threads: usize,
    pub batch: usize,
}

impl LatencyComparison {
    /// measured / modeled — a calibration signal, not an expectation of
    /// equality: the model prices a mobile GPU, the measurement a host
    /// CPU.  Trends (scheme orderings, thread scaling) are what the
    /// benches compare.
    pub fn ratio(&self) -> f64 {
        self.measured_ms / self.modeled_ms.max(1e-12)
    }
}

/// The execution backend the scheme's generated code would use for a
/// masked 2-D GEMM view.
pub fn kernel_for_scheme(masked_gemm: &Tensor, scheme: &Scheme) -> Box<dyn SparseKernel + Send> {
    match scheme {
        Scheme::None => Box::new(DenseKernel::from_tensor(masked_gemm)),
        Scheme::Unstructured => Box::new(Csr::from_dense(masked_gemm)),
        _ => Box::new(Bcs::from_dense(masked_gemm)),
    }
}

/// The [`SparseKernel::label`] the scheme would execute under, without
/// materializing a tensor — the static mirror of [`kernel_for_scheme`].
pub fn backend_for_scheme(scheme: &Scheme) -> &'static str {
    match scheme {
        Scheme::None => "dense",
        Scheme::Unstructured => "csr",
        _ => "bcs",
    }
}

/// [`layer_latency_ms`] scaled by a measured/modeled calibration ratio.
/// `scale = 1.0` (no calibration) reproduces the raw model; a layer whose
/// trace ran 3x slower than modeled is priced 3x up, so downstream
/// consumers (lint's dominant-layer and re-ranking rules) reason about
/// the machine that was actually measured.
pub fn calibrated_layer_latency_ms(
    layer: &LayerSpec,
    cfg: &ExecConfig,
    dev: &DeviceProfile,
    scale: f64,
) -> f64 {
    layer_latency_ms(layer, cfg, dev) * scale.max(0.0)
}

/// Price a set of candidate schemes for one layer at a fixed compression
/// and calibration scale, ascending by predicted latency.  Candidates
/// that are not [`Scheme::applicable`] to the layer are skipped.  This is
/// the re-ranking helper `prunemap lint` uses to ask "would a different
/// regularity have been faster here?".
pub fn rank_schemes(
    layer: &LayerSpec,
    candidates: &[Scheme],
    compression: f32,
    dev: &DeviceProfile,
    scale: f64,
) -> Vec<(Scheme, f64)> {
    let mut ranked: Vec<(Scheme, f64)> = candidates
        .iter()
        .filter(|s| s.applicable(layer))
        .map(|s| {
            let cfg = ExecConfig::new(*s, compression, dev);
            (*s, calibrated_layer_latency_ms(layer, &cfg, dev, scale))
        })
        .collect();
    ranked.sort_by(|a, b| a.1.total_cmp(&b.1));
    ranked
}

/// Execute the masked GEMM view of a layer on the batched multi-threaded
/// engine and report the measurement beside the model's prediction — the
/// hook that keeps the simulator honest about the mechanisms it prices
/// (irregularity cost, batch amortization, thread scaling).
pub fn measured_vs_modeled(
    layer: &LayerSpec,
    cfg: &ExecConfig,
    dev: &DeviceProfile,
    masked_gemm: &Tensor,
    batch: usize,
    threads: usize,
    reps: usize,
) -> LatencyComparison {
    assert_eq!(masked_gemm.ndim(), 2);
    let modeled_ms = layer_latency_ms(layer, cfg, dev);
    let kernel = kernel_for_scheme(masked_gemm, &cfg.scheme);
    let engine = Engine::new(threads);
    let cols = masked_gemm.shape()[1];
    let x: Vec<f32> = (0..cols * batch)
        .map(|i| ((i % 17) as f32) * 0.25 - 2.0)
        .collect();
    let _warmup = engine.spmm(&*kernel, &x, batch);
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        std::hint::black_box(engine.spmm(&*kernel, &x, batch));
        best = best.min(t.elapsed().as_secs_f64() * 1e3);
    }
    LatencyComparison { modeled_ms, measured_ms: best, threads: engine.threads(), batch }
}

// ---------------------------------------------------------------------
// Whole-network measured-vs-modeled
// ---------------------------------------------------------------------

use crate::accuracy::Assignment;
use crate::models::ModelSpec;
use crate::runtime::graph::{CompiledNet, GraphExecutor};
use crate::util::json::Value;

/// Whole-network calibration record: the cost model's per-kernel
/// predictions summed over a model next to a measured end-to-end run of
/// the same pruned network through [`GraphExecutor`] on the native engine.
#[derive(Debug, Clone)]
pub struct NetworkLatencyComparison {
    pub model: String,
    /// Sum of per-layer modeled latencies (mobile GPU, batch 1), ms.
    pub modeled_ms: f64,
    /// Measured whole-network wall-clock (host CPU, whole batch, min over
    /// reps), ms.
    pub measured_ms: f64,
    pub threads: usize,
    pub batch: usize,
    /// `(layer name, modeled ms)` per prunable layer.
    pub per_layer: Vec<(String, f64)>,
}

impl NetworkLatencyComparison {
    /// measured / modeled — a drift signal for BENCH trajectories, not an
    /// expectation of equality (mobile-GPU model vs host-CPU measurement).
    pub fn ratio(&self) -> f64 {
        self.measured_ms / self.modeled_ms.max(1e-12)
    }

    /// JSON record (`util::json`) so bench output can be tracked across
    /// PRs: `{"model", "modeled_ms", "measured_ms", "ratio", "threads",
    /// "batch", "per_layer": {name: ms}}`.
    pub fn to_json(&self) -> Value {
        let per_layer = Value::Obj(
            self.per_layer
                .iter()
                .map(|(n, ms)| (n.clone(), Value::num(*ms)))
                .collect(),
        );
        Value::obj(vec![
            ("model", Value::str(self.model.clone())),
            ("modeled_ms", Value::num(self.modeled_ms)),
            ("measured_ms", Value::num(self.measured_ms)),
            ("ratio", Value::num(self.ratio())),
            ("threads", Value::num(self.threads as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("per_layer", per_layer),
        ])
    }
}

/// Run a compiled network end to end on the native graph executor and
/// report the measurement beside the cost model's summed per-kernel
/// predictions — the whole-network counterpart of [`measured_vs_modeled`].
pub fn measured_vs_modeled_network(
    model: &ModelSpec,
    assigns: &[Assignment],
    dev: &DeviceProfile,
    net: &CompiledNet,
    batch: usize,
    threads: usize,
    reps: usize,
) -> crate::Result<NetworkLatencyComparison> {
    if model.layers.len() != assigns.len() {
        anyhow::bail!(
            "{} layers but {} assignments for {}",
            model.layers.len(),
            assigns.len(),
            model.name
        );
    }
    let per_layer: Vec<(String, f64)> = model
        .layers
        .iter()
        .zip(assigns)
        .map(|(l, a)| {
            let cfg = ExecConfig::new(a.scheme, a.compression, dev);
            (l.name.clone(), layer_latency_ms(l, &cfg, dev))
        })
        .collect();
    let modeled_ms: f64 = per_layer.iter().map(|(_, ms)| ms).sum();

    let exec = GraphExecutor::new(threads);
    let (c, h, w) = net.input_shape;
    let input: Vec<f32> = (0..batch * c * h * w)
        .map(|i| ((i % 17) as f32) * 0.25 - 2.0)
        .collect();
    // warm arena carried across reps: the timed runs measure the
    // steady-state (allocation-free) path, not cold-start allocation
    let mut arena = crate::runtime::Arena::new();
    let _warmup = exec.run_with_arena(net, &input, batch, &mut arena)?;
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = std::time::Instant::now();
        std::hint::black_box(exec.run_with_arena(net, &input, batch, &mut arena)?);
        best = best.min(t.elapsed().as_secs_f64() * 1e3);
    }
    Ok(NetworkLatencyComparison {
        model: model.name.clone(),
        modeled_ms,
        measured_ms: best,
        threads: exec.threads(),
        batch,
        per_layer,
    })
}

// ---------------------------------------------------------------------
// Per-layer trace calibration
// ---------------------------------------------------------------------

/// One layer's cost-model prediction next to its traced measurement.
#[derive(Debug, Clone)]
pub struct LayerCalibration {
    pub name: String,
    /// Cost-model prediction (mobile GPU, batch 1), ms.
    pub modeled_ms: f64,
    /// Measured per-layer step time (host CPU, whole batch), ms.
    pub measured_ms: f64,
}

impl LayerCalibration {
    /// measured / modeled — a per-layer drift signal, not an expectation
    /// of equality (the model prices a mobile GPU, the trace a host CPU).
    pub fn ratio(&self) -> f64 {
        self.measured_ms / self.modeled_ms.max(1e-12)
    }
}

/// Per-layer calibration record built from trace spans: each prunable
/// layer's measured step time (`prunemap profile` aggregates the
/// executor's per-step spans) matched by name against the cost model's
/// prediction for the same layer under its assigned scheme.  This is the
/// record that closes the loop between [`crate::telemetry::trace`]
/// measurements and this module's analytic model.
#[derive(Debug, Clone)]
pub struct PerLayerCalibration {
    pub model: String,
    pub threads: usize,
    pub batch: usize,
    /// Timed runs averaged into each measurement.
    pub reps: usize,
    /// One entry per prunable layer with a matching measurement.
    pub layers: Vec<LayerCalibration>,
}

impl PerLayerCalibration {
    /// Match `measured` `(step name, ms)` pairs against the model's
    /// prunable layers (non-layer steps — pools, flatten — simply don't
    /// match) and price each matched layer with the cost model.  Errors
    /// if nothing matches: an all-miss join means the caller fed spans
    /// from a different model.
    pub fn new(
        model: &ModelSpec,
        assigns: &[Assignment],
        dev: &DeviceProfile,
        measured: &[(String, f64)],
        threads: usize,
        batch: usize,
        reps: usize,
    ) -> crate::Result<PerLayerCalibration> {
        if model.layers.len() != assigns.len() {
            anyhow::bail!(
                "{} layers but {} assignments for {}",
                model.layers.len(),
                assigns.len(),
                model.name
            );
        }
        let layers: Vec<LayerCalibration> = model
            .layers
            .iter()
            .zip(assigns)
            .filter_map(|(l, a)| {
                let (_, ms) = measured.iter().find(|(name, _)| *name == l.name)?;
                let cfg = ExecConfig::new(a.scheme, a.compression, dev);
                Some(LayerCalibration {
                    name: l.name.clone(),
                    modeled_ms: layer_latency_ms(l, &cfg, dev),
                    measured_ms: *ms,
                })
            })
            .collect();
        if layers.is_empty() {
            anyhow::bail!("no measured step names match {}'s prunable layers", model.name);
        }
        Ok(PerLayerCalibration { model: model.name.clone(), threads, batch, reps, layers })
    }

    /// JSON calibration record, format-tagged so downstream readers can
    /// evolve: `{"format":"prunemap.calibration.v1","model",...,"layers":
    /// [{"name","modeled_ms","measured_ms","ratio"}]}`.
    pub fn to_json(&self) -> Value {
        let layers = Value::arr(
            self.layers
                .iter()
                .map(|l| {
                    Value::obj(vec![
                        ("name", Value::str(l.name.clone())),
                        ("modeled_ms", Value::num(l.modeled_ms)),
                        ("measured_ms", Value::num(l.measured_ms)),
                        ("ratio", Value::num(l.ratio())),
                    ])
                })
                .collect(),
        );
        Value::obj(vec![
            ("format", Value::str("prunemap.calibration.v1")),
            ("model", Value::str(self.model.clone())),
            ("threads", Value::num(self.threads as f64)),
            ("batch", Value::num(self.batch as f64)),
            ("reps", Value::num(self.reps as f64)),
            ("layers", layers),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LayerSpec;

    fn dev() -> DeviceProfile {
        DeviceProfile::s10()
    }

    fn conv3(in_hw: usize, ch: usize) -> LayerSpec {
        LayerSpec::conv("c", 3, ch, ch, in_hw, 1)
    }

    #[test]
    fn dense_faster_than_nothing_is_false_latency_positive() {
        let l = conv3(28, 128);
        let lat = layer_latency_ms(&l, &ExecConfig::dense(&dev()), &dev());
        assert!(lat > 0.0 && lat.is_finite());
    }

    #[test]
    fn compression_reduces_latency() {
        let l = conv3(28, 128);
        let d = dev();
        let dense = layer_latency_ms(&l, &ExecConfig::dense(&d), &d);
        let pruned = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::BlockPunched { bf: 16, bc: 32 }, 8.0, &d),
            &d,
        );
        assert!(pruned < dense, "pruned {pruned} >= dense {dense}");
    }

    #[test]
    fn fig5_ordering_unstructured_slowest_structured_fastest() {
        // same compression, ResNet-50-ish 3x3 layer
        let l = conv3(28, 256);
        let d = dev();
        let c = 4.0;
        let unstructured =
            layer_latency_ms(&l, &ExecConfig::new(Scheme::Unstructured, c, &d), &d);
        let small_block = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::BlockPunched { bf: 4, bc: 4 }, c, &d),
            &d,
        );
        let big_block = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::BlockPunched { bf: 32, bc: 64 }, c, &d),
            &d,
        );
        let structured =
            layer_latency_ms(&l, &ExecConfig::new(Scheme::StructuredRow, c, &d), &d);
        assert!(structured < big_block, "{structured} vs {big_block}");
        assert!(big_block < small_block, "{big_block} vs {small_block}");
        assert!(small_block < unstructured, "{small_block} vs {unstructured}");
    }

    #[test]
    fn fig9_block_size_saturation() {
        // latency decreases with block size but the marginal gain shrinks
        let l = conv3(28, 128);
        let d = dev();
        let sizes = [(4, 4), (4, 16), (8, 16), (16, 32), (32, 64)];
        let lats: Vec<f64> = sizes
            .iter()
            .map(|&(bf, bc)| {
                layer_latency_ms(
                    &l,
                    &ExecConfig::new(Scheme::BlockPunched { bf, bc }, 8.0, &d),
                    &d,
                )
            })
            .collect();
        for w in lats.windows(2) {
            assert!(w[1] < w[0], "latency must fall with block size: {lats:?}");
        }
        let first_gain = lats[0] - lats[1];
        let last_gain = lats[3] - lats[4];
        assert!(last_gain < first_gain, "saturation expected: {lats:?}");
    }

    #[test]
    fn fig9_small_feature_maps_are_slower_at_iso_macs() {
        // 56x56x64 vs 7x7x512 keep MACs equal for 3x3 convs
        let d = dev();
        let big_fm = conv3(56, 64);
        let small_fm = conv3(7, 512);
        assert_eq!(big_fm.macs(), small_fm.macs());
        let cfg = |_l: &LayerSpec| ExecConfig::new(Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0, &d);
        let a = layer_latency_ms(&big_fm, &cfg(&big_fm), &d);
        let b = layer_latency_ms(&small_fm, &cfg(&small_fm), &d);
        assert!(b > a, "7x7x512 ({b}ms) should be slower than 56x56x64 ({a}ms)");
    }

    #[test]
    fn pattern_vs_block_crossover_fig10b() {
        // paper: pattern ~ block 8x16 at 4-8x; pattern faster than small
        // blocks, slower than very large blocks
        let l = conv3(28, 128);
        let d = dev();
        let c = 8.0;
        let pattern = layer_latency_ms(&l, &ExecConfig::new(Scheme::Pattern, c, &d), &d);
        let b8x16 = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::BlockPunched { bf: 8, bc: 16 }, c, &d),
            &d,
        );
        let b4x4 = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::BlockPunched { bf: 4, bc: 4 }, c, &d),
            &d,
        );
        let b32x64 = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::BlockPunched { bf: 32, bc: 64 }, c, &d),
            &d,
        );
        let ratio = pattern / b8x16;
        assert!((0.6..1.6).contains(&ratio), "pattern/8x16 ratio {ratio}");
        assert!(pattern < b4x4);
        assert!(pattern > b32x64);
    }

    #[test]
    fn fusion_and_reordering_help() {
        let l = conv3(28, 128);
        let d = dev();
        let mut cfg = ExecConfig::new(Scheme::Unstructured, 4.0, &d);
        let tuned = layer_latency_ms(&l, &cfg, &d);
        cfg.fused = false;
        let unfused = layer_latency_ms(&l, &cfg, &d);
        cfg.fused = true;
        cfg.reordered = false;
        let unordered = layer_latency_ms(&l, &cfg, &d);
        assert!(unfused > tuned);
        assert!(unordered > tuned);
    }

    #[test]
    fn faster_devices_are_faster() {
        let l = conv3(56, 256);
        let cfg = ExecConfig::dense(&DeviceProfile::s10());
        let a = layer_latency_ms(&l, &cfg, &DeviceProfile::s10());
        let b = layer_latency_ms(&l, &cfg, &DeviceProfile::s20());
        let c = layer_latency_ms(&l, &cfg, &DeviceProfile::s21());
        assert!(a > b && b > c);
    }

    #[test]
    fn fc_is_memory_bound_and_block_size_helps() {
        let l = LayerSpec::fc("fc", 25088, 4096);
        let d = dev();
        let tiny = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::Block { bp: 1, bq: 1 }, 8.0, &d),
            &d,
        );
        let big = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::Block { bp: 64, bq: 128 }, 8.0, &d),
            &d,
        );
        assert!(big < tiny);
        // saturation: 64x128 -> 128x256 gains little
        let bigger = layer_latency_ms(
            &l,
            &ExecConfig::new(Scheme::Block { bp: 128, bq: 256 }, 8.0, &d),
            &d,
        );
        assert!((big - bigger) / big < 0.15);
    }

    #[test]
    fn measured_vs_modeled_produces_sane_numbers() {
        use crate::pruning::{prune, PatternLibrary};
        use crate::rng::Rng;
        let d = dev();
        let layer = LayerSpec::conv("c", 3, 32, 32, 14, 1);
        let cfg = ExecConfig::new(Scheme::BlockPunched { bf: 8, bc: 8 }, 4.0, &d);
        let mut rng = Rng::new(1);
        let w = crate::tensor::Tensor::he_normal(&[32, 32, 3, 3], 32 * 9, &mut rng);
        let r = prune(&w, &cfg.scheme, 4.0, &PatternLibrary::default8());
        let gemm = w.hadamard(&r.mask).conv_to_gemm();
        let c = measured_vs_modeled(&layer, &cfg, &d, &gemm, 8, 2, 3);
        assert!(c.modeled_ms > 0.0 && c.modeled_ms.is_finite());
        assert!(c.measured_ms > 0.0 && c.measured_ms.is_finite());
        assert!(c.ratio() > 0.0);
        assert_eq!(c.threads, 2);
        assert_eq!(c.batch, 8);
    }

    #[test]
    fn measured_vs_modeled_network_produces_json_record() {
        use crate::models::zoo;
        use crate::runtime::KernelChoice;
        let d = dev();
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m
            .layers
            .iter()
            .map(|_| Assignment { scheme: Scheme::Unstructured, compression: 2.0 })
            .collect();
        let net = CompiledNet::compile(&m, &assigns, 5, KernelChoice::Auto).unwrap();
        let cmp = measured_vs_modeled_network(&m, &assigns, &d, &net, 2, 2, 2).unwrap();
        assert!(cmp.modeled_ms > 0.0 && cmp.modeled_ms.is_finite());
        assert!(cmp.measured_ms > 0.0 && cmp.measured_ms.is_finite());
        assert_eq!(cmp.per_layer.len(), m.layers.len());
        let j = cmp.to_json();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "ProxyCNN");
        assert!(j.get("measured_ms").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("per_layer").unwrap().as_obj().unwrap().len(), m.layers.len());
        // the record round-trips through the parser (what BENCH readers do)
        let round = Value::parse(&j.compact()).unwrap();
        assert_eq!(round.get("batch").unwrap().as_usize().unwrap(), 2);
        assert_eq!(round.get("threads").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn per_layer_calibration_joins_measured_steps_by_name() {
        use crate::models::zoo;
        let d = dev();
        let m = zoo::proxy_cnn();
        let assigns: Vec<Assignment> = m
            .layers
            .iter()
            .map(|_| Assignment { scheme: Scheme::Unstructured, compression: 2.0 })
            .collect();
        let measured = vec![
            (m.layers[0].name.clone(), 0.5),
            // a non-prunable step (pool) simply doesn't join
            ("pool_step".to_string(), 0.1),
        ];
        let cal = PerLayerCalibration::new(&m, &assigns, &d, &measured, 2, 4, 3).unwrap();
        assert_eq!(cal.layers.len(), 1);
        assert_eq!(cal.layers[0].name, m.layers[0].name);
        assert_eq!(cal.layers[0].measured_ms, 0.5);
        assert!(cal.layers[0].modeled_ms > 0.0 && cal.layers[0].ratio() > 0.0);
        let j = Value::parse(&cal.to_json().compact()).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "prunemap.calibration.v1");
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "ProxyCNN");
        assert_eq!(j.get("layers").unwrap().as_arr().unwrap().len(), 1);
        // an all-miss join is an error, not an empty record
        let miss = vec![("zzz".to_string(), 1.0)];
        assert!(PerLayerCalibration::new(&m, &assigns, &d, &miss, 1, 1, 1).is_err());
    }

    #[test]
    fn kernel_for_scheme_picks_expected_backend() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(kernel_for_scheme(&t, &Scheme::None).label(), "dense");
        assert_eq!(kernel_for_scheme(&t, &Scheme::Unstructured).label(), "csr");
        assert_eq!(
            kernel_for_scheme(&t, &Scheme::BlockPunched { bf: 4, bc: 4 }).label(),
            "bcs"
        );
        assert_eq!(kernel_for_scheme(&t, &Scheme::Pattern).label(), "bcs");
    }

    #[test]
    fn absolute_scale_sanity() {
        // whole-model dense latencies should land in the paper's ballpark:
        // dense VGG-16/ImageNet on S10 tens of ms (PatDNN reaches 18.9ms at
        // 8x pattern), MobileNetV2 a few ms.
        use crate::models::zoo;
        let d = dev();
        let vgg = zoo::vgg16(crate::models::Dataset::ImageNet);
        let cfgs: Vec<ExecConfig> = vgg.layers.iter().map(|_| ExecConfig::dense(&d)).collect();
        let lat = model_latency_ms(&vgg.layers, &cfgs, &d);
        assert!((20.0..250.0).contains(&lat), "VGG-16 dense = {lat}ms");

        let mnv2 = zoo::mobilenet_v2(crate::models::Dataset::ImageNet);
        let cfgs: Vec<ExecConfig> = mnv2.layers.iter().map(|_| ExecConfig::dense(&d)).collect();
        let lat2 = model_latency_ms(&mnv2.layers, &cfgs, &d);
        assert!((1.5..15.0).contains(&lat2), "MobileNetV2 dense = {lat2}ms");
        assert!(lat2 < lat);
    }
}
