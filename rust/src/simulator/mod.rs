//! Mobile-SoC latency simulator — the substitution for the paper's
//! Samsung Galaxy test devices (DESIGN.md §2).
//!
//! [`device`] holds per-phone profiles (S10/S20/S21); [`cost`] is the
//! analytic execution model that turns (layer, pruning scheme, block size,
//! compression, compiler flags) into milliseconds.  The compiler's
//! auto-tuner searches this model; the latency model (crate::latmodel)
//! tabulates it; both mapping methods consume it.

pub mod cost;
pub mod device;

pub use cost::{
    backend_for_scheme, calibrated_layer_latency_ms, kernel_for_scheme, layer_latency_ms,
    measured_vs_modeled, measured_vs_modeled_network, model_latency_ms, rank_schemes, ExecConfig,
    LatencyComparison, LayerCalibration, NetworkLatencyComparison, PerLayerCalibration,
    TileParams,
};
pub use device::DeviceProfile;
