//! Coordinator: the end-to-end pipeline orchestration (paper Fig. 2).
//!
//! Ties every subsystem together:
//!
//! * [`run_pipeline`] — the live path on the proxy CNN: dense pretrain →
//!   reweighted-regularized training (host-side alpha updates between
//!   epochs) → prune (one-shot magnitude or reweighted auto-prune) →
//!   masked retrain → report, all through the AOT PJRT artifacts.
//! * [`evaluate_overlapped`] — the paper's §5.1 trick: compiler latency
//!   measurement runs concurrently with accuracy evaluation (they share no
//!   state — latency depends on structure only, "does not depend on
//!   absolute weight values"), implemented with scoped threads.

#[cfg(pjrt)]
use anyhow::Result;

use crate::accuracy::Assignment;
use crate::latmodel::LatencyModel;
use crate::mapping::{self, MappingEval};
use crate::models::ModelSpec;
#[cfg(pjrt)]
use crate::pruning::PatternLibrary;
#[cfg(pjrt)]
use crate::rng::Rng;
#[cfg(pjrt)]
use crate::runtime::Runtime;
use crate::simulator::DeviceProfile;
#[cfg(pjrt)]
use crate::train::{SynthDataset, TrainDriver};

/// Pipeline hyperparameters (laptop-scale defaults).
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub pretrain_steps: usize,
    pub reg_epochs: usize,
    pub steps_per_epoch: usize,
    pub retrain_steps: usize,
    pub lr: f32,
    /// Reweighted-penalty weight (lambda in Eq. 1).
    pub lambda: f32,
    /// Auto-prune threshold (fraction of mean group stat).
    pub tau: f32,
    pub seed: u64,
    /// Use reweighted auto-prune (true) or one-shot magnitude (false).
    pub auto_prune: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            pretrain_steps: 150,
            reg_epochs: 4,
            steps_per_epoch: 40,
            retrain_steps: 300,
            lr: 0.05,
            lambda: 2e-4,
            tau: 0.12,
            seed: 0xDADA,
            auto_prune: false,
        }
    }
}

/// Everything the end-to-end run produces.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Cross-entropy per step across all phases.
    pub loss_curve: Vec<f32>,
    pub acc_pretrained: f32,
    pub acc_after_prune: f32,
    pub acc_after_retrain: f32,
    /// Achieved per-layer compression rates.
    pub layer_compressions: Vec<f32>,
    pub overall_compression: f32,
    pub dense_latency_ms: f64,
    pub pruned_latency_ms: f64,
}

impl PipelineReport {
    pub fn speedup(&self) -> f64 {
        self.dense_latency_ms / self.pruned_latency_ms.max(1e-9)
    }
}

/// Run the full live pipeline on the proxy CNN (PJRT builds only; the
/// native-engine pipeline is exercised by the integration tests directly).
#[cfg(pjrt)]
pub fn run_pipeline(
    rt: &Runtime,
    model: &ModelSpec,
    assigns: &[Assignment],
    dev: &DeviceProfile,
    cfg: &PipelineConfig,
) -> Result<PipelineReport> {
    assert_eq!(model.layers.len(), assigns.len());
    let mut driver = TrainDriver::new(rt, cfg.seed)?;
    let ds = SynthDataset::cifar_like(cfg.seed ^ 0x0D5);
    let mut rng = Rng::new(cfg.seed ^ 0xBA7C4);
    let lib = PatternLibrary::default8();
    let mut loss_curve = Vec::new();

    // --- phase 1: dense pretrain --------------------------------------
    for _ in 0..cfg.pretrain_steps {
        let (x, y) = ds.batch(driver.batch_size(), &mut rng);
        let s = driver.step(&x, &y, cfg.lr, 0.0)?;
        loss_curve.push(s.ce);
    }
    let acc_pretrained = driver.eval_acc(&ds, 8, cfg.seed ^ 0xE7A1)?;

    // --- phase 2: reweighted-regularized training ----------------------
    for _epoch in 0..cfg.reg_epochs {
        driver.update_alphas(assigns);
        for _ in 0..cfg.steps_per_epoch {
            let (x, y) = ds.batch(driver.batch_size(), &mut rng);
            let s = driver.step(&x, &y, cfg.lr, cfg.lambda)?;
            loss_curve.push(s.ce);
        }
    }

    // --- phase 3: prune -------------------------------------------------
    let layer_compressions = if cfg.auto_prune {
        driver.auto_prune_with(assigns, cfg.tau)?
    } else {
        driver.prune_with(assigns, &lib)?
    };
    let acc_after_prune = driver.eval_acc(&ds, 8, cfg.seed ^ 0xE7A2)?;

    // --- phase 4: masked retrain ----------------------------------------
    for _ in 0..cfg.retrain_steps {
        let (x, y) = ds.batch(driver.batch_size(), &mut rng);
        let s = driver.step(&x, &y, cfg.lr, 0.0)?;
        loss_curve.push(s.ce);
    }
    let acc_after_retrain = driver.eval_acc(&ds, 8, cfg.seed ^ 0xE7A3)?;

    // --- latency ---------------------------------------------------------
    let dense_latency_ms = mapping::dense_latency_ms(model, dev);
    let achieved: Vec<Assignment> = assigns
        .iter()
        .zip(&layer_compressions)
        .map(|(a, &c)| Assignment { scheme: a.scheme, compression: c.max(1.0) })
        .collect();
    let eval = mapping::evaluate(model, &achieved, dev);

    let total: f64 = model.layers.iter().map(|l| l.params() as f64).sum();
    let kept: f64 = model
        .layers
        .iter()
        .zip(&layer_compressions)
        .map(|(l, &c)| l.params() as f64 / c.max(1.0) as f64)
        .sum();

    Ok(PipelineReport {
        loss_curve,
        acc_pretrained,
        acc_after_prune,
        acc_after_retrain,
        layer_compressions,
        overall_compression: (total / kept.max(1.0)) as f32,
        dense_latency_ms,
        pruned_latency_ms: eval.latency_ms,
    })
}

/// §5.1: "we overlap the compiler code generation and latency measurement
/// with the accuracy evaluation".  The latency leg (latency-model queries /
/// simulator) runs on its own thread while the accuracy leg computes.
pub fn evaluate_overlapped(
    model: &ModelSpec,
    assigns: &[Assignment],
    dev: &DeviceProfile,
    lat: &LatencyModel,
) -> MappingEval {
    let mut latency_ms = 0.0;
    let mut acc_drop = 0.0;
    std::thread::scope(|scope| {
        let lat_handle = scope.spawn(|| {
            model
                .layers
                .iter()
                .zip(assigns)
                .map(|(l, a)| mapping::assignment_latency(l, a, lat, dev))
                .sum::<f64>()
        });
        acc_drop = crate::accuracy::acc_drop(model, assigns);
        latency_ms = lat_handle.join().expect("latency thread panicked");
    });
    MappingEval {
        acc_drop,
        latency_ms,
        compression: crate::accuracy::overall_compression(model, assigns, false),
        macs: crate::accuracy::remaining_macs(model, assigns),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::pruning::Scheme;

    #[test]
    fn overlapped_matches_sequential() {
        let dev = DeviceProfile::s10();
        let lat = LatencyModel::build(&dev);
        let m = zoo::resnet18(crate::models::Dataset::Cifar10);
        let assigns: Vec<Assignment> = m
            .layers
            .iter()
            .map(|l| {
                if l.is_3x3_conv() {
                    Assignment {
                        scheme: Scheme::BlockPunched { bf: 8, bc: 16 },
                        compression: 8.0,
                    }
                } else {
                    Assignment::dense()
                }
            })
            .collect();
        let o = evaluate_overlapped(&m, &assigns, &dev, &lat);
        let seq: f64 = m
            .layers
            .iter()
            .zip(&assigns)
            .map(|(l, a)| mapping::assignment_latency(l, a, &lat, &dev))
            .sum();
        assert!((o.latency_ms - seq).abs() < 1e-9);
        assert_eq!(o.acc_drop, crate::accuracy::acc_drop(&m, &assigns));
    }
}
