//! Training-free rule-based mapping (paper §5.2, Fig. 8).
//!
//! Per layer:
//! 1. 3x3 depthwise CONV → no pruning (§5.2.4);
//! 2. 3x3 CONV → pattern-based on hard datasets, block-punched on easy
//!    ones (Remark 1);
//! 3. everything else → block-based (FC) / block-punched (CONV);
//! 4. when a block scheme is chosen, the block size is the **smallest**
//!    candidate whose MAC-normalized latency (from the offline latency
//!    model) is within β of coarse-grained structured pruning (§5.2.2) —
//!    hardware first, then the finest granularity that hardware allows;
//! 5. the compression rate comes from the reweighted algorithm
//!    (spec-level stand-in: accuracy::auto_compression).

use crate::accuracy::{auto_compression, Assignment};
use crate::latmodel::LatencyModel;
use crate::models::{LayerKind, LayerSpec, ModelSpec};
use crate::pruning::Scheme;

/// Rule-based mapping parameters.
#[derive(Debug, Clone, Copy)]
pub struct RuleConfig {
    /// Acceptable latency degradation vs structured pruning (paper: 20%).
    pub beta: f64,
    /// Reference compression used during block-size selection.
    pub reference_compression: f32,
}

impl Default for RuleConfig {
    fn default() -> Self {
        RuleConfig { beta: 0.20, reference_compression: 8.0 }
    }
}

/// Select the block size for one layer per §5.2.2: smallest block whose
/// normalized latency is within (1+β) of structured pruning's.  Only
/// candidates whose block dims actually tile the layer's weight
/// ([`Scheme::applicable`]) are considered; `None` when no candidate is
/// legal (e.g. a 255-filter detection head), which callers map to
/// unstructured pruning.
pub fn select_block_size(
    layer: &LayerSpec,
    lat: &LatencyModel,
    cfg: &RuleConfig,
) -> Option<(usize, usize)> {
    let comp = cfg.reference_compression;
    let structured = lat
        .latency_per_gmac(layer, &Scheme::StructuredRow, comp)
        .unwrap_or(f64::MAX);
    let mut fallback = None;
    for &(a, b) in Scheme::block_size_candidates() {
        let scheme = block_scheme(layer, a, b);
        if !scheme.applicable(layer) {
            continue;
        }
        if let Some(l) = lat.latency_per_gmac(layer, &scheme, comp) {
            if l <= structured * (1.0 + cfg.beta) {
                return Some((a, b));
            }
            fallback = Some((a, b));
        }
    }
    // nothing met the threshold: the largest legal candidate is closest
    fallback
}

/// The block-family scheme a layer kind executes: block-based for FC,
/// block-punched for CONV/depthwise (§5.2.3).
pub fn block_scheme(layer: &LayerSpec, a: usize, b: usize) -> Scheme {
    if layer.kind == LayerKind::Fc {
        Scheme::Block { bp: a, bq: b }
    } else {
        Scheme::BlockPunched { bf: a, bc: b }
    }
}

/// Every scheme either mapping method could have assigned to `layer`:
/// structured-row, pattern (3x3 CONV only), each legal entry of the
/// block-size grid, and unstructured.  Already filtered by
/// [`Scheme::applicable`] — this is the candidate set `prunemap lint`
/// re-ranks with the cost model.
pub fn candidate_schemes(layer: &LayerSpec) -> Vec<Scheme> {
    let mut out = vec![Scheme::StructuredRow, Scheme::Pattern, Scheme::Unstructured];
    for &(a, b) in Scheme::block_size_candidates() {
        out.push(block_scheme(layer, a, b));
    }
    out.retain(|s| s.applicable(layer));
    out
}

/// Map one layer (the Fig. 8 decision diamond).
pub fn map_layer(
    layer: &LayerSpec,
    model: &ModelSpec,
    lat: &LatencyModel,
    cfg: &RuleConfig,
) -> Assignment {
    // 1. never prune 3x3 depthwise
    if layer.is_3x3_dw() {
        return Assignment::dense();
    }
    // 2. 3x3 CONV: dataset difficulty decides pattern vs block
    if layer.is_3x3_conv() && model.dataset.is_hard() {
        let compression = auto_compression(layer, &Scheme::Pattern, model.dataset);
        return Assignment { scheme: Scheme::Pattern, compression };
    }
    // 3./4. block-based / block-punched with β-selected block size; a
    // layer no candidate block tiles falls back to unstructured (finest
    // granularity, always legal)
    let scheme = match select_block_size(layer, lat, cfg) {
        Some((a, b)) => block_scheme(layer, a, b),
        None => Scheme::Unstructured,
    };
    let compression = auto_compression(layer, &scheme, model.dataset);
    Assignment { scheme, compression }
}

/// Map every layer of a model.  Training-free: consumes only the offline
/// latency model and the layer specs.
pub fn map_rule_based(
    model: &ModelSpec,
    lat: &LatencyModel,
    cfg: &RuleConfig,
) -> Vec<Assignment> {
    model
        .layers
        .iter()
        .map(|l| map_layer(l, model, lat, cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Dataset};
    use crate::simulator::DeviceProfile;

    fn lat() -> LatencyModel {
        LatencyModel::build(&DeviceProfile::s10())
    }

    #[test]
    fn dw_layers_never_pruned() {
        let m = zoo::mobilenet_v2(Dataset::ImageNet);
        let assigns = map_rule_based(&m, &lat(), &RuleConfig::default());
        for (l, a) in m.layers.iter().zip(&assigns) {
            if l.is_3x3_dw() {
                assert!(matches!(a.scheme, Scheme::None), "{} pruned", l.name);
            }
        }
    }

    #[test]
    fn remark1_dataset_dispatch_for_3x3() {
        let lm = lat();
        let cfg = RuleConfig::default();
        let hard = zoo::vgg16(Dataset::ImageNet);
        let assigns = map_rule_based(&hard, &lm, &cfg);
        for (l, a) in hard.layers.iter().zip(&assigns) {
            if l.is_3x3_conv() {
                assert!(matches!(a.scheme, Scheme::Pattern), "{}: {:?}", l.name, a.scheme);
            }
        }
        let easy = zoo::vgg16(Dataset::Cifar10);
        let assigns = map_rule_based(&easy, &lm, &cfg);
        for (l, a) in easy.layers.iter().zip(&assigns) {
            if l.is_3x3_conv() {
                assert!(
                    matches!(a.scheme, Scheme::BlockPunched { .. }),
                    "{}: {:?}",
                    l.name,
                    a.scheme
                );
            }
        }
    }

    #[test]
    fn fc_gets_block_based() {
        let m = zoo::vgg16(Dataset::ImageNet);
        let assigns = map_rule_based(&m, &lat(), &RuleConfig::default());
        for (l, a) in m.layers.iter().zip(&assigns) {
            if l.kind == LayerKind::Fc {
                assert!(matches!(a.scheme, Scheme::Block { .. }), "{}: {:?}", l.name, a.scheme);
            }
        }
    }

    #[test]
    fn one_by_one_gets_block_punched() {
        let m = zoo::mobilenet_v2(Dataset::ImageNet);
        let assigns = map_rule_based(&m, &lat(), &RuleConfig::default());
        for (l, a) in m.layers.iter().zip(&assigns) {
            if l.kind == LayerKind::Conv && l.kh == 1 {
                assert!(
                    matches!(a.scheme, Scheme::BlockPunched { .. }),
                    "{}: {:?}",
                    l.name,
                    a.scheme
                );
            }
        }
    }

    #[test]
    fn beta_controls_block_size() {
        let lm = lat();
        let layer = LayerSpec::conv("c", 1, 256, 256, 14, 1);
        let strict = RuleConfig { beta: 0.02, reference_compression: 8.0 };
        let loose = RuleConfig { beta: 2.0, reference_compression: 8.0 };
        let (a1, b1) = select_block_size(&layer, &lm, &strict).unwrap();
        let (a2, b2) = select_block_size(&layer, &lm, &loose).unwrap();
        assert!(
            a1 * b1 >= a2 * b2,
            "strict beta must pick an equal-or-larger block: {a1}x{b1} vs {a2}x{b2}"
        );
    }

    #[test]
    fn untileable_layers_fall_back_to_unstructured() {
        // a 255-filter detection head: no candidate bf divides 255
        let lm = lat();
        let cfg = RuleConfig::default();
        let head = LayerSpec::conv("head", 1, 256, 255, 13, 1);
        assert_eq!(select_block_size(&head, &lm, &cfg), None);
        let m = zoo::yolov4();
        let assigns = map_rule_based(&m, &lm, &cfg);
        let mut fell_back = 0;
        for (l, a) in m.layers.iter().zip(&assigns) {
            assert!(a.scheme.applicable(l), "{}: {:?} illegal", l.name, a.scheme);
            if l.out_ch == 255 {
                assert!(matches!(a.scheme, Scheme::Unstructured), "{}: {:?}", l.name, a.scheme);
                fell_back += 1;
            }
        }
        assert_eq!(fell_back, 3, "yolov4 has three detection heads");
    }

    #[test]
    fn cifar_compressions_land_high() {
        // Table 4: CIFAR-10 rule-based compressions are ~7-12x
        let m = zoo::resnet50(Dataset::Cifar10);
        let assigns = map_rule_based(&m, &lat(), &RuleConfig::default());
        let c = crate::accuracy::overall_compression(&m, &assigns, false);
        assert!((6.0..16.0).contains(&c), "compression {c}");
    }

    #[test]
    fn imagenet_compressions_land_moderate() {
        let m = zoo::resnet50(Dataset::ImageNet);
        let assigns = map_rule_based(&m, &lat(), &RuleConfig::default());
        let c = crate::accuracy::overall_compression(&m, &assigns, false);
        assert!((2.5..9.0).contains(&c), "compression {c}");
    }

    #[test]
    fn mapping_beats_dense_latency() {
        let dev = DeviceProfile::s10();
        let m = zoo::resnet50(Dataset::ImageNet);
        let assigns = map_rule_based(&m, &lat(), &RuleConfig::default());
        let eval = crate::mapping::evaluate(&m, &assigns, &dev);
        let dense = crate::mapping::dense_latency_ms(&m, &dev);
        assert!(eval.latency_ms < dense, "{} !< {}", eval.latency_ms, dense);
        // and accuracy stays near baseline
        assert!(eval.acc_drop < 0.02, "drop {}", eval.acc_drop);
    }
}
