//! Automatic pruning-scheme mapping (paper §5) — the headline contribution.
//!
//! Two methods produce per-layer [`crate::accuracy::Assignment`]s:
//!
//! * [`rule`]  — training-free (Fig. 8): latency-model-driven block-size
//!   selection with the β threshold, dataset-difficulty dispatch for 3x3
//!   CONV layers, never prunes 3x3-DW.
//! * [`search`] — REINFORCE policy-gradient search over {regularity,
//!   block size} per layer, rewarding accuracy minus latency (§5.1).
//!
//! Compression rates are *not* part of either search space: the reweighted
//! dynamic regularization discovers them (crate::reweighted for the live
//! path; accuracy::auto_compression for the spec-level path).

pub mod rule;
pub mod search;

pub use rule::{map_rule_based, RuleConfig};
pub use search::{map_search_based, SearchConfig};

use crate::accuracy::Assignment;
use crate::latmodel::LatencyModel;
use crate::models::ModelSpec;
use crate::simulator::{model_latency_ms, DeviceProfile, ExecConfig};

/// Summary of a mapping's quality.
#[derive(Debug, Clone, Copy)]
pub struct MappingEval {
    pub acc_drop: f32,
    pub latency_ms: f64,
    pub compression: f32,
    pub macs: f64,
}

/// Evaluate a full mapping: (accuracy drop, latency ms, compression).
pub fn evaluate(model: &ModelSpec, assigns: &[Assignment], dev: &DeviceProfile) -> MappingEval {
    let cfgs: Vec<ExecConfig> = assigns
        .iter()
        .map(|a| ExecConfig::new(a.scheme, a.compression, dev))
        .collect();
    MappingEval {
        acc_drop: crate::accuracy::acc_drop(model, assigns),
        latency_ms: model_latency_ms(&model.layers, &cfgs, dev),
        compression: crate::accuracy::overall_compression(model, assigns, false),
        macs: crate::accuracy::remaining_macs(model, assigns),
    }
}

/// Latency of the dense model (baseline for speedup claims).
pub fn dense_latency_ms(model: &ModelSpec, dev: &DeviceProfile) -> f64 {
    let cfgs: Vec<ExecConfig> =
        model.layers.iter().map(|_| ExecConfig::dense(dev)).collect();
    model_latency_ms(&model.layers, &cfgs, dev)
}

/// Shared helper: query latency-model latency for an assignment, falling
/// back to the simulator when the table has no entry.
pub fn assignment_latency(
    layer: &crate::models::LayerSpec,
    a: &Assignment,
    lat: &LatencyModel,
    dev: &DeviceProfile,
) -> f64 {
    lat.query(layer, &a.scheme, a.compression).unwrap_or_else(|| {
        crate::simulator::layer_latency_ms(
            layer,
            &ExecConfig::new(a.scheme, a.compression, dev),
            dev,
        )
    })
}
