//! Automatic pruning-scheme mapping (paper §5) — the headline contribution.
//!
//! Two methods produce per-layer [`crate::accuracy::Assignment`]s:
//!
//! * [`rule`]  — training-free (Fig. 8): latency-model-driven block-size
//!   selection with the β threshold, dataset-difficulty dispatch for 3x3
//!   CONV layers, never prunes 3x3-DW.
//! * [`search`] — REINFORCE policy-gradient search over {regularity,
//!   block size} per layer, rewarding accuracy minus latency (§5.1).
//!
//! Compression rates are *not* part of either search space: the reweighted
//! dynamic regularization discovers them (crate::reweighted for the live
//! path; accuracy::auto_compression for the spec-level path).

pub mod rule;
pub mod search;

pub use rule::{block_scheme, candidate_schemes, map_rule_based, RuleConfig};
pub use search::{map_search_based, SearchConfig};

use anyhow::{anyhow, Result};

use crate::accuracy::Assignment;
use crate::latmodel::LatencyModel;
use crate::models::ModelSpec;
use crate::simulator::{model_latency_ms, DeviceProfile, ExecConfig};
use crate::util::cli::Args;

/// A mapping method plus its configuration: the one place the
/// `"rule"`-vs-`"search"` dispatch lives.  The CLI (`prunemap map`,
/// `prunemap infer`, `prunemap serve`) and
/// [`serve::PreparedModel::builder`](crate::serve::PreparedModel::builder)
/// all resolve method names through here instead of hand-rolling the match.
#[derive(Debug, Clone)]
pub enum MappingMethod {
    /// Training-free rule-based mapping (Fig. 8) over the device's offline
    /// latency model.
    Rule(RuleConfig),
    /// REINFORCE policy-gradient search (§5.1).
    Search(SearchConfig),
}

impl MappingMethod {
    /// Resolve a method name (`"rule"` | `"search"`); `iterations` and
    /// `seed` configure the search variant and are ignored by the rule
    /// variant.
    pub fn parse(name: &str, iterations: usize, seed: u64) -> Result<MappingMethod> {
        match name {
            "rule" => Ok(MappingMethod::Rule(RuleConfig::default())),
            "search" => Ok(MappingMethod::Search(SearchConfig {
                iterations,
                seed,
                ..Default::default()
            })),
            other => Err(anyhow!("unknown method '{other}' (rule|search)")),
        }
    }

    /// [`MappingMethod::parse`] from parsed CLI arguments: `--method` with
    /// `--iterations` (falling back to `default_iterations`); the search
    /// seed is resolved by the caller (commands differ on which flag names
    /// it).
    pub fn from_args(
        args: &Args,
        default_iterations: usize,
        search_seed: u64,
    ) -> Result<MappingMethod> {
        Self::parse(
            args.get_or("method", "rule"),
            args.get_usize("iterations", default_iterations)?,
            search_seed,
        )
    }

    /// Short display name (`"rule"` | `"search"`).
    pub fn label(&self) -> &'static str {
        match self {
            MappingMethod::Rule(_) => "rule",
            MappingMethod::Search(_) => "search",
        }
    }

    /// Run the method end to end: per-layer assignments for `model` on
    /// `dev`.  The rule variant builds the device's latency model
    /// internally.
    pub fn assign(&self, model: &ModelSpec, dev: &DeviceProfile) -> Vec<Assignment> {
        match self {
            MappingMethod::Rule(cfg) => {
                let lat = LatencyModel::build(dev);
                map_rule_based(model, &lat, cfg)
            }
            MappingMethod::Search(cfg) => map_search_based(model, dev, cfg).0,
        }
    }
}

/// Summary of a mapping's quality.
#[derive(Debug, Clone, Copy)]
pub struct MappingEval {
    pub acc_drop: f32,
    pub latency_ms: f64,
    pub compression: f32,
    pub macs: f64,
}

/// Evaluate a full mapping: (accuracy drop, latency ms, compression).
pub fn evaluate(model: &ModelSpec, assigns: &[Assignment], dev: &DeviceProfile) -> MappingEval {
    let cfgs: Vec<ExecConfig> = assigns
        .iter()
        .map(|a| ExecConfig::new(a.scheme, a.compression, dev))
        .collect();
    MappingEval {
        acc_drop: crate::accuracy::acc_drop(model, assigns),
        latency_ms: model_latency_ms(&model.layers, &cfgs, dev),
        compression: crate::accuracy::overall_compression(model, assigns, false),
        macs: crate::accuracy::remaining_macs(model, assigns),
    }
}

/// Latency of the dense model (baseline for speedup claims).
pub fn dense_latency_ms(model: &ModelSpec, dev: &DeviceProfile) -> f64 {
    let cfgs: Vec<ExecConfig> =
        model.layers.iter().map(|_| ExecConfig::dense(dev)).collect();
    model_latency_ms(&model.layers, &cfgs, dev)
}

/// Shared helper: query latency-model latency for an assignment, falling
/// back to the simulator when the table has no entry.
pub fn assignment_latency(
    layer: &crate::models::LayerSpec,
    a: &Assignment,
    lat: &LatencyModel,
    dev: &DeviceProfile,
) -> f64 {
    lat.query(layer, &a.scheme, a.compression).unwrap_or_else(|| {
        crate::simulator::layer_latency_ms(
            layer,
            &ExecConfig::new(a.scheme, a.compression, dev),
            dev,
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_and_label() {
        assert!(matches!(MappingMethod::parse("rule", 0, 0).unwrap(), MappingMethod::Rule(_)));
        let m = MappingMethod::parse("search", 17, 42).unwrap();
        match &m {
            MappingMethod::Search(cfg) => {
                assert_eq!(cfg.iterations, 17);
                assert_eq!(cfg.seed, 42);
            }
            other => panic!("expected search, got {other:?}"),
        }
        assert_eq!(m.label(), "search");
        assert!(MappingMethod::parse("magic", 0, 0).is_err());
    }

    #[test]
    fn method_from_args_reads_method_and_iterations() {
        let toks = |s: &str| s.split_whitespace().map(str::to_string).collect::<Vec<_>>();
        let a = Args::parse(toks("--method search --iterations 9"));
        match MappingMethod::from_args(&a, 30, 7).unwrap() {
            MappingMethod::Search(cfg) => {
                assert_eq!(cfg.iterations, 9);
                assert_eq!(cfg.seed, 7);
            }
            other => panic!("expected search, got {other:?}"),
        }
        let d = Args::parse(toks(""));
        assert!(matches!(
            MappingMethod::from_args(&d, 30, 7).unwrap(),
            MappingMethod::Rule(_)
        ));
    }
}
