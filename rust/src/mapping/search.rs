//! Search-based mapping via REINFORCE policy gradients (paper §5.1).
//!
//! The RL agent observes per-layer state {layer type, kernel size, input
//! channels, output channels} (plus size features) and emits a 2-D action
//! {pruning regularity, block size} per layer.  The policy is a shared
//! tanh-MLP with two softmax heads, trained with the score-function
//! estimator and a moving-average baseline (Eq. 6):
//!
//!   ∇J ≈ (1/K) Σ_k (R(M_k) − B) ∇ log π(M_k | I; θ)
//!
//! (The paper parameterizes π as an encoder/decoder RNN; with per-layer
//! state vectors and a shared trunk the policy is equivalent for this
//! action space and trains in seconds — DESIGN.md notes the substitution.)
//!
//! The reward is the weighted sum of accuracy and negative latency; the
//! fast evaluation path (one-shot magnitude pruning + 2-epoch retrain in
//! the paper) is the calibrated accuracy model here, and the latency term
//! comes from the same device cost model the rule-based method tabulates.
//! The live proxy-CNN reward path is wired in crate::coordinator.

use crate::accuracy::{acc_drop, auto_compression, Assignment};
use crate::models::{LayerKind, LayerSpec, ModelSpec};
use crate::pruning::Scheme;
use crate::rng::Rng;
use crate::simulator::{model_latency_ms, DeviceProfile, ExecConfig};

const N_FEATURES: usize = 8;
const HIDDEN: usize = 16;
/// Regularity actions: 0 = block (block-based/punched), 1 = pattern,
/// 2 = unstructured, 3 = structured.
const N_REG: usize = 4;

/// Search hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct SearchConfig {
    pub iterations: usize,
    /// Mappings sampled per iteration (K in Eq. 6).
    pub samples: usize,
    pub lr: f32,
    /// Latency weight in the reward.
    pub lambda: f32,
    pub seed: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig { iterations: 60, samples: 8, lr: 0.05, lambda: 2.0, seed: 0xC0FFEE }
    }
}

/// State featurization (§5.1's 4-D state + log-scale size features).
fn features(layer: &LayerSpec) -> [f32; N_FEATURES] {
    let mut f = [0f32; N_FEATURES];
    f[0] = layer.is_3x3_conv() as u8 as f32;
    f[1] = layer.is_3x3_dw() as u8 as f32;
    f[2] = (layer.kind == LayerKind::Fc) as u8 as f32;
    f[3] = (layer.kind == LayerKind::Conv && !layer.is_3x3_conv()) as u8 as f32;
    f[4] = (layer.params() as f32).log2() / 24.0;
    f[5] = (layer.out_ch as f32).log2() / 12.0;
    f[6] = ((layer.in_hw + 1) as f32).log2() / 8.0;
    f[7] = ((layer.kh * layer.kw) as f32).log2() / 6.0;
    f
}

/// The policy network: shared trunk + regularity head + block-size head.
#[derive(Debug, Clone)]
pub struct Policy {
    w1: Vec<f32>, // HIDDEN x N_FEATURES
    b1: Vec<f32>,
    wr: Vec<f32>, // N_REG x HIDDEN
    br: Vec<f32>,
    wb: Vec<f32>, // N_BLOCK x HIDDEN
    bb: Vec<f32>,
    n_block: usize,
}

/// Gradients, same layout as Policy.
struct Grads {
    w1: Vec<f32>,
    b1: Vec<f32>,
    wr: Vec<f32>,
    br: Vec<f32>,
    wb: Vec<f32>,
    bb: Vec<f32>,
}

fn softmax_masked(logits: &[f32], valid: &[bool]) -> Vec<f32> {
    let mut m = f32::NEG_INFINITY;
    for (l, &v) in logits.iter().zip(valid) {
        if v && *l > m {
            m = *l;
        }
    }
    let mut e: Vec<f32> = logits
        .iter()
        .zip(valid)
        .map(|(l, &v)| if v { (l - m).exp() } else { 0.0 })
        .collect();
    let z: f32 = e.iter().sum::<f32>().max(1e-12);
    for x in &mut e {
        *x /= z;
    }
    e
}

impl Policy {
    pub fn new(seed: u64) -> Policy {
        let n_block = Scheme::block_size_candidates().len();
        let mut rng = Rng::new(seed);
        let mut init = |n: usize, fan: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() * (1.0 / fan as f32).sqrt()).collect()
        };
        Policy {
            w1: init(HIDDEN * N_FEATURES, N_FEATURES),
            b1: vec![0.0; HIDDEN],
            wr: init(N_REG * HIDDEN, HIDDEN),
            br: vec![0.0; N_REG],
            wb: init(n_block * HIDDEN, HIDDEN),
            bb: vec![0.0; n_block],
            n_block,
        }
    }

    fn forward(&self, x: &[f32; N_FEATURES]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut h = vec![0f32; HIDDEN];
        for i in 0..HIDDEN {
            let mut acc = self.b1[i];
            for j in 0..N_FEATURES {
                acc += self.w1[i * N_FEATURES + j] * x[j];
            }
            h[i] = acc.tanh();
        }
        let mut lr = vec![0f32; N_REG];
        for i in 0..N_REG {
            let mut acc = self.br[i];
            for j in 0..HIDDEN {
                acc += self.wr[i * HIDDEN + j] * h[j];
            }
            lr[i] = acc;
        }
        let mut lb = vec![0f32; self.n_block];
        for i in 0..self.n_block {
            let mut acc = self.bb[i];
            for j in 0..HIDDEN {
                acc += self.wb[i * HIDDEN + j] * h[j];
            }
            lb[i] = acc;
        }
        (h, lr, lb)
    }

    fn valid_regularities(layer: &LayerSpec) -> [bool; N_REG] {
        [
            // block (punched for conv, block for fc): only when at least
            // one candidate block size tiles the weight
            valid_blocks(layer).iter().any(|&v| v),
            layer.is_3x3_conv(), // pattern
            true,                // unstructured
            true,                // structured
        ]
    }

    /// The block-size mask shared by sampling and the gradient pass; all
    /// true when no candidate is legal (the head is inert then — the
    /// block regularity itself is masked out).
    fn block_mask(&self, layer: &LayerSpec) -> Vec<bool> {
        let vb = valid_blocks(layer);
        if vb.iter().any(|&v| v) {
            vb
        } else {
            vec![true; self.n_block]
        }
    }

    /// Sample (or greedy-decode) an action for a layer.
    fn act(&self, layer: &LayerSpec, rng: Option<&mut Rng>) -> (usize, usize) {
        let x = features(layer);
        let (_, lr, lb) = self.forward(&x);
        let vr = Self::valid_regularities(layer);
        let pr = softmax_masked(&lr, &vr);
        let vb = self.block_mask(layer);
        let pb = softmax_masked(&lb, &vb);
        match rng {
            Some(rng) => (rng.categorical(&pr), rng.categorical(&pb)),
            None => (
                pr.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap(),
                pb.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap(),
            ),
        }
    }

    /// Accumulate ∇ log π(action | layer) * advantage into `g`.
    fn accumulate_grad(
        &self,
        layer: &LayerSpec,
        action: (usize, usize),
        advantage: f32,
        g: &mut Grads,
    ) {
        let x = features(layer);
        let (h, lr, lb) = self.forward(&x);
        let vr = Self::valid_regularities(layer);
        let pr = softmax_masked(&lr, &vr);
        let pb = softmax_masked(&lb, &self.block_mask(layer));

        // d log softmax = onehot - p   (masked-out entries have p = 0)
        let mut dh = vec![0f32; HIDDEN];
        for i in 0..N_REG {
            if !vr[i] {
                continue;
            }
            let gi = ((i == action.0) as u8 as f32 - pr[i]) * advantage;
            g.br[i] += gi;
            for j in 0..HIDDEN {
                g.wr[i * HIDDEN + j] += gi * h[j];
                dh[j] += gi * self.wr[i * HIDDEN + j];
            }
        }
        // block head contributes only when the block regularity was chosen
        if action.0 == 0 {
            for i in 0..self.n_block {
                let gi = ((i == action.1) as u8 as f32 - pb[i]) * advantage;
                g.bb[i] += gi;
                for j in 0..HIDDEN {
                    g.wb[i * HIDDEN + j] += gi * h[j];
                    dh[j] += gi * self.wb[i * HIDDEN + j];
                }
            }
        }
        // through tanh
        for i in 0..HIDDEN {
            let dpre = dh[i] * (1.0 - h[i] * h[i]);
            g.b1[i] += dpre;
            for j in 0..N_FEATURES {
                g.w1[i * N_FEATURES + j] += dpre * x[j];
            }
        }
    }

    fn apply(&mut self, g: &Grads, lr: f32) {
        let upd = |w: &mut [f32], g: &[f32]| {
            for (wi, gi) in w.iter_mut().zip(g) {
                *wi += lr * gi;
            }
        };
        upd(&mut self.w1, &g.w1);
        upd(&mut self.b1, &g.b1);
        upd(&mut self.wr, &g.wr);
        upd(&mut self.br, &g.br);
        upd(&mut self.wb, &g.wb);
        upd(&mut self.bb, &g.bb);
    }

    fn zero_grads(&self) -> Grads {
        Grads {
            w1: vec![0.0; self.w1.len()],
            b1: vec![0.0; self.b1.len()],
            wr: vec![0.0; self.wr.len()],
            br: vec![0.0; self.br.len()],
            wb: vec![0.0; self.wb.len()],
            bb: vec![0.0; self.bb.len()],
        }
    }
}

/// The block scheme candidate `idx` denotes for this layer's kind.
fn block_candidate(layer: &LayerSpec, idx: usize) -> Scheme {
    let (a, b) = Scheme::block_size_candidates()[idx];
    if layer.kind == LayerKind::Fc {
        Scheme::Block { bp: a, bq: b }
    } else {
        Scheme::BlockPunched { bf: a, bc: b }
    }
}

/// Per-candidate legality of the block action for one layer
/// ([`Scheme::applicable`] — block dims must tile the weight).
fn valid_blocks(layer: &LayerSpec) -> Vec<bool> {
    (0..Scheme::block_size_candidates().len())
        .map(|i| block_candidate(layer, i).applicable(layer))
        .collect()
}

/// Decode an action pair into an assignment for a layer.
fn decode(layer: &LayerSpec, model: &ModelSpec, action: (usize, usize)) -> Assignment {
    // the rule of never pruning 3x3-DW is a hard constraint in both methods
    if layer.is_3x3_dw() {
        return Assignment::dense();
    }
    let mut scheme = match action.0 {
        0 => block_candidate(layer, action.1),
        1 => Scheme::Pattern,
        2 => Scheme::Unstructured,
        _ => Scheme::StructuredRow,
    };
    // the action masks keep sampled actions legal; decode stays total
    // anyway so a hand-rolled action can't produce an illegal assignment
    if !scheme.applicable(layer) {
        scheme = Scheme::Unstructured;
    }
    let compression = auto_compression(layer, &scheme, model.dataset);
    Assignment { scheme, compression }
}

/// Reward of a full mapping (higher is better): weighted accuracy minus
/// normalized latency (§5.1).
pub fn reward(
    model: &ModelSpec,
    assigns: &[Assignment],
    dev: &DeviceProfile,
    dense_ms: f64,
    lambda: f32,
) -> f32 {
    let drop_pct = acc_drop(model, assigns) * 100.0;
    let cfgs: Vec<ExecConfig> = assigns
        .iter()
        .map(|a| ExecConfig::new(a.scheme, a.compression, dev))
        .collect();
    let lat = model_latency_ms(&model.layers, &cfgs, dev);
    -drop_pct - lambda * (lat / dense_ms) as f32
}

/// Run the search; returns (assignments, final policy, reward trace).
pub fn map_search_based(
    model: &ModelSpec,
    dev: &DeviceProfile,
    cfg: &SearchConfig,
) -> (Vec<Assignment>, Policy, Vec<f32>) {
    let mut policy = Policy::new(cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let dense_ms = super::dense_latency_ms(model, dev);
    let mut baseline = 0.0f32;
    let mut initialized = false;
    let mut trace = Vec::with_capacity(cfg.iterations);

    for _iter in 0..cfg.iterations {
        let mut g = policy.zero_grads();
        let mut mean_r = 0.0;
        let mut episodes: Vec<(Vec<(usize, usize)>, f32)> = Vec::with_capacity(cfg.samples);
        for _k in 0..cfg.samples {
            let actions: Vec<(usize, usize)> = model
                .layers
                .iter()
                .map(|l| policy.act(l, Some(&mut rng)))
                .collect();
            let assigns: Vec<Assignment> = model
                .layers
                .iter()
                .zip(&actions)
                .map(|(l, &a)| decode(l, model, a))
                .collect();
            let r = reward(model, &assigns, dev, dense_ms, cfg.lambda);
            mean_r += r / cfg.samples as f32;
            episodes.push((actions, r));
        }
        if !initialized {
            baseline = mean_r;
            initialized = true;
        }
        for (actions, r) in &episodes {
            let adv = (r - baseline) / cfg.samples as f32;
            for (layer, &action) in model.layers.iter().zip(actions) {
                if layer.is_3x3_dw() {
                    continue; // hard-constrained, no learning signal
                }
                policy.accumulate_grad(layer, action, adv, &mut g);
            }
        }
        policy.apply(&g, cfg.lr);
        baseline = 0.9 * baseline + 0.1 * mean_r;
        trace.push(mean_r);
    }

    // greedy decode
    let assigns: Vec<Assignment> = model
        .layers
        .iter()
        .map(|l| decode(l, model, policy.act(l, None)))
        .collect();
    (assigns, policy, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Dataset};

    fn quick_cfg() -> SearchConfig {
        SearchConfig { iterations: 30, samples: 6, lr: 0.08, lambda: 2.0, seed: 42 }
    }

    #[test]
    fn search_reward_improves() {
        let dev = DeviceProfile::s10();
        let m = zoo::resnet18(Dataset::Cifar10);
        let (_, _, trace) = map_search_based(&m, &dev, &quick_cfg());
        let head: f32 = trace[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = trace[trace.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(tail > head, "reward did not improve: {head} -> {tail}");
    }

    #[test]
    fn search_respects_dw_constraint() {
        let dev = DeviceProfile::s10();
        let m = zoo::mobilenet_v2(Dataset::Cifar10);
        let (assigns, _, _) = map_search_based(&m, &dev, &quick_cfg());
        for (l, a) in m.layers.iter().zip(&assigns) {
            if l.is_3x3_dw() {
                assert!(matches!(a.scheme, Scheme::None));
            }
        }
    }

    #[test]
    fn search_never_emits_pattern_off_3x3() {
        let dev = DeviceProfile::s10();
        let m = zoo::mobilenet_v2(Dataset::ImageNet);
        let (assigns, _, _) = map_search_based(&m, &dev, &quick_cfg());
        for (l, a) in m.layers.iter().zip(&assigns) {
            if matches!(a.scheme, Scheme::Pattern) {
                assert!(l.is_3x3_conv(), "{}: pattern on non-3x3", l.name);
            }
        }
    }

    #[test]
    fn search_never_emits_an_illegal_block() {
        let dev = DeviceProfile::s10();
        // 255 filters: no candidate bf divides them, so the block
        // regularity must be masked out for this layer
        let layers = vec![
            crate::models::LayerSpec::conv("head", 1, 256, 255, 13, 1),
            crate::models::LayerSpec::fc("fc", 128, 10),
        ];
        let m = ModelSpec { name: "odd".into(), dataset: Dataset::Cifar10, layers };
        let (assigns, _, _) = map_search_based(&m, &dev, &quick_cfg());
        for (l, a) in m.layers.iter().zip(&assigns) {
            assert!(a.scheme.applicable(l), "{}: {:?} illegal", l.name, a.scheme);
        }
        // a hand-rolled illegal action still decodes to a legal scheme
        let a = decode(&m.layers[0], &m, (0, 0));
        assert!(matches!(a.scheme, Scheme::Unstructured), "{:?}", a.scheme);
    }

    #[test]
    fn search_deterministic_for_seed() {
        let dev = DeviceProfile::s10();
        let m = zoo::resnet18(Dataset::Cifar10);
        let (a1, _, _) = map_search_based(&m, &dev, &quick_cfg());
        let (a2, _, _) = map_search_based(&m, &dev, &quick_cfg());
        for (x, y) in a1.iter().zip(&a2) {
            assert_eq!(x.scheme, y.scheme);
        }
    }

    #[test]
    fn search_beats_or_matches_naive_uniform() {
        // paper: search-based >= applying one scheme everywhere
        let dev = DeviceProfile::s10();
        let m = zoo::resnet50(Dataset::Cifar10);
        let cfg = SearchConfig { iterations: 80, ..quick_cfg() };
        let (assigns, _, _) = map_search_based(&m, &dev, &cfg);
        let dense_ms = crate::mapping::dense_latency_ms(&m, &dev);
        let searched = reward(&m, &assigns, &dev, dense_ms, cfg.lambda);
        let uniform: Vec<Assignment> = m
            .layers
            .iter()
            .map(|l| {
                let s = Scheme::Unstructured;
                Assignment {
                    scheme: s,
                    compression: auto_compression(l, &s, m.dataset),
                }
            })
            .collect();
        let base = reward(&m, &uniform, &dev, dense_ms, cfg.lambda);
        assert!(searched >= base, "searched {searched} < uniform-unstructured {base}");
    }
}
