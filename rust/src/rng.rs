//! Deterministic pseudo-random number generation (SplitMix64 + xoshiro256**).
//!
//! Everything in this crate that needs randomness — weight init, synthetic
//! datasets, GA mutation, RL sampling — goes through [`Rng`] so every
//! experiment is reproducible from a single seed.  No external crates, no
//! global state.

/// SplitMix64-seeded xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for parallel workers / sub-tasks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut u = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f32 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.03, "p2={p2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
