//! DNN model specifications: the layer-level view the mapping methods
//! operate on.
//!
//! The paper's two mapping methods consume only *per-layer structural
//! information* — layer type, kernel size, channel counts, feature-map size
//! (the RL state vector of §5.1) — plus params/MACs accounting (Fig. 3,
//! Tables 4-5).  This module defines that representation and a zoo of the
//! evaluated networks: VGG-16, ResNet-18/50, MobileNet-V1/V2 (CIFAR-10 and
//! ImageNet variants), YOLOv4, and the FC layers of Fig. 10a.

pub mod zoo;

pub use zoo::*;

/// Layer category, the first element of the paper's RL state vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Standard convolution (possibly 1x1 / 5x5 / 7x7).
    Conv,
    /// Depthwise convolution (one filter per input channel).
    DepthwiseConv,
    /// Fully connected / linear.
    Fc,
}

/// One prunable layer of a network.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSpec {
    pub name: String,
    pub kind: LayerKind,
    /// Kernel height/width (1 for FC).
    pub kh: usize,
    pub kw: usize,
    /// Input channels (FC: input features).
    pub in_ch: usize,
    /// Output channels / filters (FC: output features).
    pub out_ch: usize,
    /// Input feature-map spatial size (FC: 1).
    pub in_hw: usize,
    /// Convolution stride (FC: 1).
    pub stride: usize,
}

impl LayerSpec {
    pub fn conv(name: &str, k: usize, in_ch: usize, out_ch: usize, in_hw: usize, stride: usize) -> Self {
        LayerSpec {
            name: name.to_string(),
            kind: LayerKind::Conv,
            kh: k,
            kw: k,
            in_ch,
            out_ch,
            in_hw,
            stride,
        }
    }

    pub fn dwconv(name: &str, k: usize, ch: usize, in_hw: usize, stride: usize) -> Self {
        LayerSpec {
            name: name.to_string(),
            kind: LayerKind::DepthwiseConv,
            kh: k,
            kw: k,
            in_ch: ch,
            out_ch: ch,
            in_hw,
            stride,
        }
    }

    pub fn fc(name: &str, in_features: usize, out_features: usize) -> Self {
        LayerSpec {
            name: name.to_string(),
            kind: LayerKind::Fc,
            kh: 1,
            kw: 1,
            in_ch: in_features,
            out_ch: out_features,
            in_hw: 1,
            stride: 1,
        }
    }

    /// Output feature-map size (SAME padding assumed, as in the zoo nets).
    pub fn out_hw(&self) -> usize {
        if self.kind == LayerKind::Fc {
            1
        } else {
            self.in_hw.div_ceil(self.stride)
        }
    }

    /// Weight-parameter count (biases excluded — they are never pruned).
    pub fn params(&self) -> usize {
        match self.kind {
            LayerKind::Conv => self.out_ch * self.in_ch * self.kh * self.kw,
            LayerKind::DepthwiseConv => self.out_ch * self.kh * self.kw,
            LayerKind::Fc => self.in_ch * self.out_ch,
        }
    }

    /// Multiply-accumulate count for one inference.
    pub fn macs(&self) -> usize {
        let out_hw = self.out_hw();
        match self.kind {
            LayerKind::Conv => self.out_ch * self.in_ch * self.kh * self.kw * out_hw * out_hw,
            LayerKind::DepthwiseConv => self.out_ch * self.kh * self.kw * out_hw * out_hw,
            LayerKind::Fc => self.in_ch * self.out_ch,
        }
    }

    /// Is this a regular 3x3 CONV (pattern-based pruning's only habitat)?
    pub fn is_3x3_conv(&self) -> bool {
        self.kind == LayerKind::Conv && self.kh == 3 && self.kw == 3
    }

    /// Is this a 3x3 depthwise CONV (never pruned by the rule-based method)?
    pub fn is_3x3_dw(&self) -> bool {
        self.kind == LayerKind::DepthwiseConv && self.kh == 3 && self.kw == 3
    }

    /// GEMM-view dimensions (rows = C*KH*KW, cols = F), the shape the BCS
    /// format and the latency model reason about.
    pub fn gemm_dims(&self) -> (usize, usize) {
        match self.kind {
            LayerKind::Fc => (self.in_ch, self.out_ch),
            LayerKind::Conv => (self.in_ch * self.kh * self.kw, self.out_ch),
            LayerKind::DepthwiseConv => (self.kh * self.kw, self.out_ch),
        }
    }
}

/// Dataset difficulty drives the rule-based 3x3 decision (Remark 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    Cifar10,
    Cifar100,
    ImageNet,
    Coco,
    Synthetic,
}

impl Dataset {
    /// CLI/serialization name; inverse of [`Dataset::by_name`].
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Cifar10 => "cifar10",
            Dataset::Cifar100 => "cifar100",
            Dataset::ImageNet => "imagenet",
            Dataset::Coco => "coco",
            Dataset::Synthetic => "synthetic",
        }
    }

    /// Look a dataset up by its CLI name (case-insensitive); `None` for
    /// unknown names.  The single registry `main.rs`, the serve builder,
    /// and artifact deserialization share.
    pub fn by_name(name: &str) -> Option<Dataset> {
        Some(match name.to_ascii_lowercase().as_str() {
            "cifar10" => Dataset::Cifar10,
            "cifar100" => Dataset::Cifar100,
            "imagenet" => Dataset::ImageNet,
            "coco" => Dataset::Coco,
            "synthetic" => Dataset::Synthetic,
            _ => return None,
        })
    }

    /// "Hard" datasets prefer pattern-based pruning on 3x3 layers
    /// (paper §5.2.3: ImageNet-class tasks where even unpruned nets stay
    /// under ~80% top-1).
    pub fn is_hard(&self) -> bool {
        matches!(self, Dataset::ImageNet | Dataset::Coco)
    }

    /// Number of classifier outputs a head trained on this dataset must
    /// produce; `None` where the output is not a class vector (COCO
    /// detection heads, synthetic proxies).  The static analyzer's
    /// `output-classes` rule compares a compiled net's output length
    /// against this.
    pub fn num_classes(&self) -> Option<usize> {
        match self {
            Dataset::Cifar10 => Some(10),
            Dataset::Cifar100 => Some(100),
            Dataset::ImageNet => Some(1000),
            Dataset::Coco | Dataset::Synthetic => None,
        }
    }

    /// Baseline top-1 accuracy of a well-trained reference model — the
    /// anchor for the analytic accuracy model.
    pub fn baseline_acc(&self) -> f32 {
        match self {
            Dataset::Cifar10 => 0.946,
            Dataset::Cifar100 => 0.78,
            Dataset::ImageNet => 0.761,
            Dataset::Coco => 0.573, // mAP for YOLOv4
            Dataset::Synthetic => 0.95,
        }
    }
}

/// A whole network: ordered prunable layers + metadata.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub dataset: Dataset,
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    /// Published baseline top-1 accuracy (mAP for YOLOv4) for the exact
    /// (network, dataset) pairs the paper evaluates; falls back to the
    /// dataset-level anchor otherwise.
    pub fn baseline_acc(&self) -> f32 {
        match (self.name.as_str(), self.dataset) {
            ("ResNet-50", Dataset::Cifar10) => 0.956,
            ("VGG-16", Dataset::Cifar10) => 0.939,
            ("MobileNetV2", Dataset::Cifar10) => 0.946,
            ("ResNet-50", Dataset::ImageNet) => 0.761,
            ("VGG-16", Dataset::ImageNet) => 0.745,
            ("MobileNetV2", Dataset::ImageNet) => 0.710,
            ("ResNet-18", Dataset::ImageNet) => 0.698,
            ("MobileNet-V1", Dataset::ImageNet) => 0.709,
            _ => self.dataset.baseline_acc(),
        }
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params()).sum()
    }

    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Fraction of weight parameters living in 3x3 CONV layers (Fig. 3a).
    pub fn frac_params_3x3(&self) -> f32 {
        let three: usize = self
            .layers
            .iter()
            .filter(|l| l.is_3x3_conv())
            .map(|l| l.params())
            .sum();
        three as f32 / self.total_params().max(1) as f32
    }

    /// Fraction of MACs in 3x3 CONV layers (Fig. 3b).
    pub fn frac_macs_3x3(&self) -> f32 {
        let three: usize = self
            .layers
            .iter()
            .filter(|l| l.is_3x3_conv())
            .map(|l| l.macs())
            .sum();
        three as f32 / self.total_macs().max(1) as f32
    }

    /// Fraction of params in 3x3 depthwise layers (§5.2.4 discussion).
    pub fn frac_params_dw(&self) -> f32 {
        let dw: usize = self
            .layers
            .iter()
            .filter(|l| l.is_3x3_dw())
            .map(|l| l.params())
            .sum();
        dw as f32 / self.total_params().max(1) as f32
    }

    /// Fraction of MACs in 3x3 depthwise layers.
    pub fn frac_macs_dw(&self) -> f32 {
        let dw: usize = self
            .layers
            .iter()
            .filter(|l| l.is_3x3_dw())
            .map(|l| l.macs())
            .sum();
        dw as f32 / self.total_macs().max(1) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_accounting() {
        let l = LayerSpec::conv("c", 3, 64, 128, 56, 1);
        assert_eq!(l.params(), 128 * 64 * 9);
        assert_eq!(l.macs(), 128 * 64 * 9 * 56 * 56);
        assert!(l.is_3x3_conv());
        assert_eq!(l.gemm_dims(), (64 * 9, 128));
    }

    #[test]
    fn stride_shrinks_output() {
        let l = LayerSpec::conv("c", 3, 8, 8, 56, 2);
        assert_eq!(l.out_hw(), 28);
        let odd = LayerSpec::conv("c", 3, 8, 8, 7, 2);
        assert_eq!(odd.out_hw(), 4);
    }

    #[test]
    fn dw_accounting() {
        let l = LayerSpec::dwconv("d", 3, 32, 28, 1);
        assert_eq!(l.params(), 32 * 9);
        assert!(l.is_3x3_dw());
        assert!(!l.is_3x3_conv());
    }

    #[test]
    fn fc_accounting() {
        let l = LayerSpec::fc("f", 1024, 128);
        assert_eq!(l.params(), 1024 * 128);
        assert_eq!(l.macs(), 1024 * 128);
        assert_eq!(l.gemm_dims(), (1024, 128));
    }

    #[test]
    fn dataset_difficulty() {
        assert!(Dataset::ImageNet.is_hard());
        assert!(Dataset::Coco.is_hard());
        assert!(!Dataset::Cifar10.is_hard());
    }

    #[test]
    fn class_counts() {
        assert_eq!(Dataset::Cifar10.num_classes(), Some(10));
        assert_eq!(Dataset::Cifar100.num_classes(), Some(100));
        assert_eq!(Dataset::ImageNet.num_classes(), Some(1000));
        assert_eq!(Dataset::Coco.num_classes(), None);
        assert_eq!(Dataset::Synthetic.num_classes(), None);
    }

    #[test]
    fn dataset_names_roundtrip() {
        for ds in [
            Dataset::Cifar10,
            Dataset::Cifar100,
            Dataset::ImageNet,
            Dataset::Coco,
            Dataset::Synthetic,
        ] {
            assert_eq!(Dataset::by_name(ds.name()), Some(ds));
        }
        assert_eq!(Dataset::by_name("CIFAR10"), Some(Dataset::Cifar10));
        assert_eq!(Dataset::by_name("mnist"), None);
    }
}
