//! The model zoo: every network the paper evaluates, as layer lists.
//!
//! Channel configurations follow the original architecture papers
//! (Simonyan'14, He'16, Sandler'18, Howard'17, Bochkovskiy'20); parameter
//! totals are asserted against the published counts in tests (within a few
//! percent — we count prunable weights only, no biases/BN).

use super::{Dataset, LayerSpec, ModelSpec};

/// VGG-16. ImageNet variant: 13 conv + 3 FC (4096/4096/1000);
/// CIFAR variant: 13 conv + 1 FC(512,10) as commonly used for CIFAR-10.
pub fn vgg16(dataset: Dataset) -> ModelSpec {
    let mut layers = Vec::new();
    let (mut hw, cifar) = match dataset {
        Dataset::ImageNet | Dataset::Coco => (224, false),
        _ => (32, true),
    };
    let cfg: &[(usize, usize)] = &[
        // (out_ch, convs in stage)
        (64, 2),
        (128, 2),
        (256, 3),
        (512, 3),
        (512, 3),
    ];
    let mut in_ch = 3;
    for (si, &(out_ch, n)) in cfg.iter().enumerate() {
        for ci in 0..n {
            layers.push(LayerSpec::conv(
                &format!("conv{}_{}", si + 1, ci + 1),
                3,
                in_ch,
                out_ch,
                hw,
                1,
            ));
            in_ch = out_ch;
        }
        hw /= 2; // maxpool
    }
    if cifar {
        layers.push(LayerSpec::fc("fc1", 512, 10));
    } else {
        layers.push(LayerSpec::fc("fc1", 512 * 7 * 7, 4096));
        layers.push(LayerSpec::fc("fc2", 4096, 4096));
        layers.push(LayerSpec::fc("fc3", 4096, 1000));
    }
    ModelSpec { name: "VGG-16".into(), dataset, layers }
}

/// ResNet-18 (basic blocks, [2,2,2,2]).
pub fn resnet18(dataset: Dataset) -> ModelSpec {
    let mut layers = Vec::new();
    let imagenet = matches!(dataset, Dataset::ImageNet | Dataset::Coco);
    let mut hw;
    let mut in_ch;
    if imagenet {
        layers.push(LayerSpec::conv("conv1", 7, 3, 64, 224, 2));
        hw = 56; // after stride-2 conv + maxpool
        in_ch = 64;
    } else {
        layers.push(LayerSpec::conv("conv1", 3, 3, 64, 32, 1));
        hw = 32;
        in_ch = 64;
    }
    let stages = [(64, 2), (128, 2), (256, 2), (512, 2)];
    for (si, &(ch, blocks)) in stages.iter().enumerate() {
        for bi in 0..blocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let pre = format!("layer{}_{}", si + 1, bi);
            layers.push(LayerSpec::conv(&format!("{pre}_conv1"), 3, in_ch, ch, hw, stride));
            let out_hw = hw.div_ceil(stride);
            layers.push(LayerSpec::conv(&format!("{pre}_conv2"), 3, ch, ch, out_hw, 1));
            if stride != 1 || in_ch != ch {
                layers.push(LayerSpec::conv(&format!("{pre}_down"), 1, in_ch, ch, hw, stride));
            }
            in_ch = ch;
            hw = out_hw;
        }
    }
    let classes = if imagenet { 1000 } else { 10 };
    layers.push(LayerSpec::fc("fc", 512, classes));
    ModelSpec { name: "ResNet-18".into(), dataset, layers }
}

/// ResNet-50 (bottleneck blocks, [3,4,6,3]).
pub fn resnet50(dataset: Dataset) -> ModelSpec {
    let mut layers = Vec::new();
    let imagenet = matches!(dataset, Dataset::ImageNet | Dataset::Coco);
    let mut hw;
    let mut in_ch;
    if imagenet {
        layers.push(LayerSpec::conv("conv1", 7, 3, 64, 224, 2));
        hw = 56;
        in_ch = 64;
    } else {
        layers.push(LayerSpec::conv("conv1", 3, 3, 64, 32, 1));
        hw = 32;
        in_ch = 64;
    }
    let stages = [(64usize, 3usize), (128, 4), (256, 6), (512, 3)];
    for (si, &(width, blocks)) in stages.iter().enumerate() {
        let out_ch = width * 4;
        for bi in 0..blocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let pre = format!("layer{}_{}", si + 1, bi);
            layers.push(LayerSpec::conv(&format!("{pre}_conv1"), 1, in_ch, width, hw, 1));
            layers.push(LayerSpec::conv(&format!("{pre}_conv2"), 3, width, width, hw, stride));
            let out_hw = hw.div_ceil(stride);
            layers.push(LayerSpec::conv(&format!("{pre}_conv3"), 1, width, out_ch, out_hw, 1));
            if stride != 1 || in_ch != out_ch {
                layers.push(LayerSpec::conv(&format!("{pre}_down"), 1, in_ch, out_ch, hw, stride));
            }
            in_ch = out_ch;
            hw = out_hw;
        }
    }
    let classes = if imagenet { 1000 } else { 10 };
    layers.push(LayerSpec::fc("fc", 2048, classes));
    ModelSpec { name: "ResNet-50".into(), dataset, layers }
}

/// MobileNet-V1 (optionally width-scaled, e.g. 0.5x / 0.75x).
pub fn mobilenet_v1_scaled(dataset: Dataset, width: f32) -> ModelSpec {
    let s = |c: usize| ((c as f32 * width).round() as usize).max(8);
    let mut layers = Vec::new();
    let imagenet = matches!(dataset, Dataset::ImageNet | Dataset::Coco);
    let mut hw = if imagenet { 224 } else { 32 };
    layers.push(LayerSpec::conv("conv1", 3, 3, s(32), hw, if imagenet { 2 } else { 1 }));
    if imagenet {
        hw = 112;
    }
    // (out_ch, stride) pairs for the 13 dw-separable blocks
    let cfg = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let mut in_ch = s(32);
    for (i, &(out_ch, stride)) in cfg.iter().enumerate() {
        let stride = if imagenet { stride } else { if stride == 2 && hw <= 4 { 1 } else { stride } };
        layers.push(LayerSpec::dwconv(&format!("dw{}", i + 1), 3, in_ch, hw, stride));
        hw = hw.div_ceil(stride);
        layers.push(LayerSpec::conv(&format!("pw{}", i + 1), 1, in_ch, s(out_ch), hw, 1));
        in_ch = s(out_ch);
    }
    let classes = if imagenet { 1000 } else { 10 };
    layers.push(LayerSpec::fc("fc", in_ch, classes));
    ModelSpec {
        name: if (width - 1.0).abs() < 1e-6 {
            "MobileNet-V1".into()
        } else {
            format!("MobileNet-V1 {width:.2}x")
        },
        dataset,
        layers,
    }
}

pub fn mobilenet_v1(dataset: Dataset) -> ModelSpec {
    mobilenet_v1_scaled(dataset, 1.0)
}

/// MobileNet-V2 (inverted residuals; optionally width-scaled).
pub fn mobilenet_v2_scaled(dataset: Dataset, width: f32) -> ModelSpec {
    let s = |c: usize| ((c as f32 * width / 8.0).round() as usize * 8).max(8);
    let mut layers = Vec::new();
    let imagenet = matches!(dataset, Dataset::ImageNet | Dataset::Coco);
    let mut hw = if imagenet { 224 } else { 32 };
    layers.push(LayerSpec::conv("conv1", 3, 3, s(32), hw, if imagenet { 2 } else { 1 }));
    if imagenet {
        hw = 112;
    }
    // (expansion t, out_ch c, repeats n, stride s)
    let cfg: &[(usize, usize, usize, usize)] = &[
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut in_ch = s(32);
    let mut blk = 0;
    for &(t, c, n, first_stride) in cfg {
        for r in 0..n {
            let stride = if r == 0 { first_stride } else { 1 };
            let stride = if !imagenet && hw <= 4 { 1 } else { stride };
            let hidden = in_ch * t;
            blk += 1;
            if t != 1 {
                layers.push(LayerSpec::conv(&format!("b{blk}_expand"), 1, in_ch, hidden, hw, 1));
            }
            layers.push(LayerSpec::dwconv(&format!("b{blk}_dw"), 3, hidden, hw, stride));
            hw = hw.div_ceil(stride);
            layers.push(LayerSpec::conv(&format!("b{blk}_project"), 1, hidden, s(c), hw, 1));
            in_ch = s(c);
        }
    }
    let last = s(1280).max(1280.min(s(1280)));
    layers.push(LayerSpec::conv("conv_last", 1, in_ch, last, hw, 1));
    let classes = if imagenet { 1000 } else { 10 };
    layers.push(LayerSpec::fc("fc", last, classes));
    ModelSpec {
        name: if (width - 1.0).abs() < 1e-6 {
            "MobileNetV2".into()
        } else {
            format!("MobileNetV2 {width:.2}x")
        },
        dataset,
        layers,
    }
}

pub fn mobilenet_v2(dataset: Dataset) -> ModelSpec {
    mobilenet_v2_scaled(dataset, 1.0)
}

/// YOLOv4: CSPDarknet53 backbone + SPP/PANet neck + YOLO heads.
/// A faithful layer-level rendering (kernel sizes, channels, strides) —
/// total prunable weights land near the paper's reported 64.36M.
pub fn yolov4() -> ModelSpec {
    let mut layers: Vec<LayerSpec> = Vec::new();
    let mut idx = 0;
    let mut conv = |layers: &mut Vec<LayerSpec>, k: usize, ic: usize, oc: usize, hw: usize, s: usize| {
        idx += 1;
        layers.push(LayerSpec::conv(&format!("conv{idx}"), k, ic, oc, hw, s));
    };
    let input = 608;
    // --- CSPDarknet53 backbone ---
    conv(&mut layers, 3, 3, 32, input, 1);
    // stage template: downsample 3x3/s2, then CSP split with n residual
    // blocks (each 1x1 + 3x3), then transition 1x1s.
    let stages: &[(usize, usize, usize)] = &[
        // (out_ch, num_res_blocks, in_hw)
        (64, 1, 608),
        (128, 2, 304),
        (256, 8, 152),
        (512, 8, 76),
        (1024, 4, 38),
    ];
    let mut in_ch = 32;
    for &(oc, nblocks, hw) in stages {
        conv(&mut layers, 3, in_ch, oc, hw, 2);
        let half = if nblocks == 1 { oc } else { oc / 2 };
        let hw2 = hw / 2;
        // CSP split paths
        conv(&mut layers, 1, oc, half, hw2, 1);
        conv(&mut layers, 1, oc, half, hw2, 1);
        for _ in 0..nblocks {
            conv(&mut layers, 1, half, half, hw2, 1);
            conv(&mut layers, 3, half, half, hw2, 1);
        }
        conv(&mut layers, 1, half, half, hw2, 1);
        conv(&mut layers, 1, half * 2, oc, hw2, 1);
        in_ch = oc;
    }
    // --- SPP + PANet neck (19x19, 38x38, 76x76 maps) ---
    conv(&mut layers, 1, 1024, 512, 19, 1);
    conv(&mut layers, 3, 512, 1024, 19, 1);
    conv(&mut layers, 1, 1024, 512, 19, 1);
    // SPP concat -> 2048
    conv(&mut layers, 1, 2048, 512, 19, 1);
    conv(&mut layers, 3, 512, 1024, 19, 1);
    conv(&mut layers, 1, 1024, 512, 19, 1);
    // upsample path to 38x38
    conv(&mut layers, 1, 512, 256, 19, 1);
    conv(&mut layers, 1, 512, 256, 38, 1);
    for _ in 0..2 {
        conv(&mut layers, 1, 512, 256, 38, 1);
        conv(&mut layers, 3, 256, 512, 38, 1);
    }
    conv(&mut layers, 1, 512, 256, 38, 1);
    // upsample path to 76x76
    conv(&mut layers, 1, 256, 128, 38, 1);
    conv(&mut layers, 1, 256, 128, 76, 1);
    for _ in 0..2 {
        conv(&mut layers, 1, 256, 128, 76, 1);
        conv(&mut layers, 3, 128, 256, 76, 1);
    }
    conv(&mut layers, 1, 256, 128, 76, 1);
    // head 76x76
    conv(&mut layers, 3, 128, 256, 76, 1);
    conv(&mut layers, 1, 256, 255, 76, 1);
    // downsample path back to 38x38
    conv(&mut layers, 3, 128, 256, 76, 2);
    for _ in 0..2 {
        conv(&mut layers, 1, 512, 256, 38, 1);
        conv(&mut layers, 3, 256, 512, 38, 1);
    }
    conv(&mut layers, 1, 512, 256, 38, 1);
    conv(&mut layers, 3, 256, 512, 38, 1);
    conv(&mut layers, 1, 512, 255, 38, 1);
    // downsample path back to 19x19
    conv(&mut layers, 3, 256, 512, 38, 2);
    for _ in 0..2 {
        conv(&mut layers, 1, 1024, 512, 19, 1);
        conv(&mut layers, 3, 512, 1024, 19, 1);
    }
    conv(&mut layers, 1, 1024, 512, 19, 1);
    conv(&mut layers, 3, 512, 1024, 19, 1);
    conv(&mut layers, 1, 1024, 255, 19, 1);
    ModelSpec { name: "YOLOv4".into(), dataset: Dataset::Coco, layers }
}

/// The two FC layers of Fig. 10a: VGG-16's first FC and BERT-base's
/// intermediate FC.
pub fn fig10a_fc_layers() -> Vec<LayerSpec> {
    vec![
        LayerSpec::fc("vgg16_fc1", 25088, 4096),
        LayerSpec::fc("bert_fc", 768, 3072),
    ]
}

/// Look a zoo model up by its CLI name (case-insensitive); `None` for
/// unknown names.  The single registry `main.rs` and the serve builder
/// share (YOLOv4 and the proxy CNN carry their own dataset and ignore
/// `dataset`).
pub fn by_name(name: &str, dataset: Dataset) -> Option<ModelSpec> {
    Some(match name.to_ascii_lowercase().as_str() {
        "vgg16" => vgg16(dataset),
        "resnet18" => resnet18(dataset),
        "resnet50" => resnet50(dataset),
        "mobilenetv1" => mobilenet_v1(dataset),
        "mobilenetv2" => mobilenet_v2(dataset),
        "yolov4" => yolov4(),
        "proxy" => proxy_cnn(),
        _ => return None,
    })
}

/// The proxy CNN trained end-to-end via the AOT artifacts (matches
/// python/compile/model.py PARAM_SPECS).
pub fn proxy_cnn() -> ModelSpec {
    ModelSpec {
        name: "ProxyCNN".into(),
        dataset: Dataset::Synthetic,
        layers: vec![
            LayerSpec::conv("conv1", 3, 3, 16, 32, 1),
            LayerSpec::conv("conv2", 3, 16, 32, 16, 1),
            LayerSpec::conv("conv3", 3, 32, 64, 8, 1),
            LayerSpec::fc("fc1", 1024, 128),
            LayerSpec::fc("fc2", 128, 10),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(actual: usize, expect_m: f32, tol: f32) -> bool {
        let a = actual as f32 / 1e6;
        (a - expect_m).abs() / expect_m < tol
    }

    #[test]
    fn vgg16_imagenet_params() {
        let m = vgg16(Dataset::ImageNet);
        // ~138M including FCs (weights only ≈ 138.3M)
        assert!(approx(m.total_params(), 138.3, 0.03), "{}", m.total_params());
        // MACs ~15.5G (conv-dominated)
        assert!(approx(m.total_macs(), 15_500.0 * 1e0, 0.05), "{}", m.total_macs());
    }

    #[test]
    fn resnet50_imagenet_params() {
        let m = resnet50(Dataset::ImageNet);
        // ~25.5M params, ~4.1G MACs
        assert!(approx(m.total_params(), 25.0, 0.10), "{}", m.total_params());
        assert!(approx(m.total_macs(), 4_100.0, 0.10), "{}", m.total_macs());
        // paper: only ~44.3% of ResNet-50 params are in 3x3 CONV layers
        let f = m.frac_params_3x3();
        assert!((0.35..0.55).contains(&f), "frac={f}");
    }

    #[test]
    fn resnet18_imagenet_params() {
        let m = resnet18(Dataset::ImageNet);
        assert!(approx(m.total_params(), 11.2, 0.10), "{}", m.total_params());
        // ResNet-18 is 3x3-dominated, unlike ResNet-50
        assert!(m.frac_params_3x3() > 0.9, "{}", m.frac_params_3x3());
    }

    #[test]
    fn mobilenet_v2_imagenet_params() {
        let m = mobilenet_v2(Dataset::ImageNet);
        // ~3.5M params, ~300M MACs
        assert!(approx(m.total_params(), 3.4, 0.15), "{}", m.total_params());
        assert!(approx(m.total_macs(), 300.0, 0.15), "{}", m.total_macs());
        // paper §5.2.4: 3x3-DW layers hold ~1.7-1.9% of params, ~6.9% of MACs
        let p = m.frac_params_dw();
        let c = m.frac_macs_dw();
        assert!((0.01..0.035).contains(&p), "dw params frac={p}");
        assert!((0.04..0.10).contains(&c), "dw macs frac={c}");
        // no regular 3x3 convs except the stem
        assert!(m.frac_params_3x3() < 0.05);
    }

    #[test]
    fn by_name_covers_the_zoo() {
        for name in ["vgg16", "resnet18", "resnet50", "mobilenetv1", "mobilenetv2"] {
            let m = by_name(name, Dataset::Cifar10).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(m.dataset, Dataset::Cifar10);
        }
        assert_eq!(by_name("yolov4", Dataset::Cifar10).unwrap().dataset, Dataset::Coco);
        assert_eq!(by_name("PROXY", Dataset::Cifar10).unwrap().name, "ProxyCNN");
        assert!(by_name("alexnet", Dataset::Cifar10).is_none());
    }

    #[test]
    fn mobilenet_v1_params() {
        let m = mobilenet_v1(Dataset::ImageNet);
        assert!(approx(m.total_params(), 4.2, 0.15), "{}", m.total_params());
        let half = mobilenet_v1_scaled(Dataset::ImageNet, 0.5);
        assert!(half.total_params() < m.total_params() / 3);
        // 0.5x MobileNetV1 ≈ 150M MACs (Table 5 anchor)
        assert!(approx(half.total_macs(), 150.0, 0.25), "{}", half.total_macs());
    }

    #[test]
    fn yolov4_params_near_paper() {
        let m = yolov4();
        // Table 2: 64.36M weights
        assert!(approx(m.total_params(), 64.36, 0.12), "{}", m.total_params());
        // mixed kernel sizes: 3x3 fraction well below 1
        let f = m.frac_params_3x3();
        assert!((0.5..0.95).contains(&f), "frac={f}");
    }

    #[test]
    fn cifar_variants_shrink() {
        assert!(vgg16(Dataset::Cifar10).total_params() < vgg16(Dataset::ImageNet).total_params());
        assert!(
            resnet50(Dataset::Cifar10).total_macs() < resnet50(Dataset::ImageNet).total_macs()
        );
    }

    #[test]
    fn proxy_matches_python_manifest_counts() {
        let m = proxy_cnn();
        let params: usize = m.total_params();
        // conv: 16*3*9 + 32*16*9 + 64*32*9 = 432+4608+18432; fc: 1024*128 + 128*10
        assert_eq!(params, 432 + 4608 + 18432 + 131072 + 1280);
    }

    #[test]
    fn all_models_have_positive_layers() {
        for m in [
            vgg16(Dataset::ImageNet),
            vgg16(Dataset::Cifar10),
            resnet18(Dataset::ImageNet),
            resnet18(Dataset::Cifar10),
            resnet50(Dataset::ImageNet),
            resnet50(Dataset::Cifar10),
            mobilenet_v1(Dataset::ImageNet),
            mobilenet_v2(Dataset::ImageNet),
            mobilenet_v2(Dataset::Cifar10),
            yolov4(),
            proxy_cnn(),
        ] {
            assert!(!m.layers.is_empty());
            for l in &m.layers {
                assert!(l.params() > 0, "{} {}", m.name, l.name);
                assert!(l.macs() > 0);
            }
        }
    }
}
