//! Minimal dense f32 tensor used across the coordinator.
//!
//! This is deliberately small: row-major storage, shape metadata, and the
//! handful of views the pruning / sparse modules need (2-D GEMM view of 4-D
//! CONV weights, block iteration).  Heavy numerics live in the AOT-compiled
//! XLA artifacts; this type exists for weight manipulation, masking, and
//! the simulator, not for fast math.

use crate::rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// One-filled tensor.
    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    /// Build from raw data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data len {}",
            shape,
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    /// He-normal init (std = sqrt(2 / fan_in)).
    pub fn he_normal(shape: &[usize], fan_in: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / fan_in.max(1) as f32).sqrt();
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Self { shape: shape.to_vec(), data }
    }

    /// Uniform init in [lo, hi).
    pub fn uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.range_f32(lo, hi)).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[r * self.shape[1] + c]
    }

    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.data[r * cols + c] = v;
    }

    /// 4-D accessor for CONV weights in (F, C, KH, KW) layout.
    pub fn at4(&self, f: usize, c: usize, kh: usize, kw: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 4);
        let (_, cs, hs, ws) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        self.data[((f * cs + c) * hs + kh) * ws + kw]
    }

    pub fn set4(&mut self, f: usize, c: usize, kh: usize, kw: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 4);
        let (cs, hs, ws) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((f * cs + c) * hs + kh) * ws + kw] = v;
    }

    /// GEMM view of a 4-D CONV weight: (F, C, KH, KW) -> (C*KH*KW, F),
    /// matching the im2col layout used by the L1 kernel.
    pub fn conv_to_gemm(&self) -> Tensor {
        assert_eq!(self.ndim(), 4);
        let (f, c, kh, kw) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let rows = c * kh * kw;
        let mut out = vec![0.0f32; rows * f];
        for fi in 0..f {
            for r in 0..rows {
                out[r * f + fi] = self.data[fi * rows + r];
            }
        }
        Tensor { shape: vec![rows, f], data: out }
    }

    /// Inverse of [`conv_to_gemm`]: (C*KH*KW, F) -> (F, C, KH, KW).
    pub fn gemm_to_conv(&self, c: usize, kh: usize, kw: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let rows = self.shape[0];
        let f = self.shape[1];
        assert_eq!(rows, c * kh * kw);
        let mut out = vec![0.0f32; f * rows];
        for fi in 0..f {
            for r in 0..rows {
                out[fi * rows + r] = self.data[r * f + fi];
            }
        }
        Tensor { shape: vec![f, c, kh, kw], data: out }
    }

    /// 2-D transpose.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = self.data[r * cols + c];
            }
        }
        Tensor { shape: vec![cols, rows], data: out }
    }

    /// Dense mat-vec reference: `y = A x` for a 2-D tensor.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.ndim(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        assert_eq!(x.len(), cols);
        (0..rows)
            .map(|r| {
                self.data[r * cols..(r + 1) * cols]
                    .iter()
                    .zip(x)
                    .map(|(w, v)| w * v)
                    .sum()
            })
            .collect()
    }

    /// Dense batched product `Y = A · X` against a `[cols, batch]`
    /// row-major input — the reference the sparse execution engine is
    /// validated against.
    pub fn matmul_cols(&self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(self.ndim(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        assert_eq!(x.len(), cols * batch, "X must be [cols, batch] row-major");
        let mut y = vec![0.0f32; rows * batch];
        for r in 0..rows {
            let row = &self.data[r * cols..(r + 1) * cols];
            let yrow = &mut y[r * batch..(r + 1) * batch];
            for (c, &w) in row.iter().enumerate() {
                for (o, &xv) in yrow.iter_mut().zip(&x[c * batch..(c + 1) * batch]) {
                    *o += w * xv;
                }
            }
        }
        y
    }

    /// Count of non-zero elements.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    /// Fraction of zero elements.
    pub fn sparsity(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        1.0 - self.nnz() as f32 / self.data.len() as f32
    }

    /// Element-wise product (used for masking); shapes must match.
    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a * b)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Squared Frobenius norm.
    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_ones_shapes() {
        let z = Tensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert_eq!(z.nnz(), 0);
        let o = Tensor::ones(&[4]);
        assert_eq!(o.nnz(), 4);
        assert_eq!(o.sparsity(), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn accessors_roundtrip() {
        let mut t = Tensor::zeros(&[3, 4]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.data()[1 * 4 + 2], 5.0);

        let mut c = Tensor::zeros(&[2, 3, 3, 3]);
        c.set4(1, 2, 0, 1, 7.0);
        assert_eq!(c.at4(1, 2, 0, 1), 7.0);
    }

    #[test]
    fn conv_gemm_roundtrip() {
        let mut rng = Rng::new(3);
        let w = Tensor::he_normal(&[6, 4, 3, 3], 36, &mut rng);
        let g = w.conv_to_gemm();
        assert_eq!(g.shape(), &[4 * 9, 6]);
        let back = g.gemm_to_conv(4, 3, 3);
        assert_eq!(back, w);
    }

    #[test]
    fn gemm_view_layout_matches_kernel() {
        // w[f, c, kh, kw] must land at gemm[(c*KH+kh)*KW+kw, f]
        let mut w = Tensor::zeros(&[2, 2, 3, 3]);
        w.set4(1, 0, 2, 1, 9.0);
        let g = w.conv_to_gemm();
        assert_eq!(g.at2((0 * 3 + 2) * 3 + 1, 1), 9.0);
    }

    #[test]
    fn transpose2_roundtrip_and_layout() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let tt = t.transpose2();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at2(0, 1), 4.0);
        assert_eq!(tt.transpose2(), t);
    }

    #[test]
    fn matvec_and_matmul_cols_agree() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 0.0, 2.0, -1.0, 3.0, 0.5]);
        let x = vec![2.0, 1.0, -1.0];
        let y = t.matvec(&x);
        assert_eq!(y, vec![0.0, 0.5]);
        // batch of two columns packed [cols, batch]
        let xb = vec![2.0, 0.0, 1.0, 1.0, -1.0, 0.0];
        let yb = t.matmul_cols(&xb, 2);
        assert_eq!(yb.len(), 4);
        assert!((yb[0] - y[0]).abs() < 1e-6 && (yb[2] - y[1]).abs() < 1e-6);
        // second column: A · [0, 1, 0] = column 1 of A
        assert!((yb[1] - 0.0).abs() < 1e-6 && (yb[3] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn hadamard_masks() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let m = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let p = t.hadamard(&m);
        assert_eq!(p.data(), &[1.0, 0.0, 0.0, 4.0]);
        assert_eq!(p.sparsity(), 0.5);
    }

    #[test]
    fn he_normal_scale() {
        let mut rng = Rng::new(1);
        let t = Tensor::he_normal(&[64, 64], 64, &mut rng);
        let var = t.sq_norm() / t.len() as f32;
        let expect = 2.0 / 64.0;
        assert!((var - expect).abs() < expect * 0.2, "var={var} expect={expect}");
    }
}
