//! Table / figure text renderers used by the CLI and the benches to print
//! the paper's tables and figure series.

/// A fixed-width text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// A figure rendered as aligned (x, series...) columns plus a crude ASCII
/// sparkline per series for shape reading.
#[derive(Debug, Clone)]
pub struct Figure {
    pub title: String,
    pub x_label: String,
    pub x: Vec<String>,
    pub series: Vec<(String, Vec<f64>)>,
}

impl Figure {
    pub fn new(title: &str, x_label: &str) -> Figure {
        Figure {
            title: title.to_string(),
            x_label: x_label.to_string(),
            x: Vec::new(),
            series: Vec::new(),
        }
    }

    pub fn set_x<S: ToString>(&mut self, xs: &[S]) {
        self.x = xs.iter().map(|s| s.to_string()).collect();
    }

    pub fn add_series(&mut self, name: &str, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.x.len(), "series length mismatch");
        self.series.push((name.to_string(), ys));
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            &self.title,
            &std::iter::once(self.x_label.as_str())
                .chain(self.series.iter().map(|(n, _)| n.as_str()))
                .collect::<Vec<_>>(),
        );
        for (i, x) in self.x.iter().enumerate() {
            let mut row = vec![x.clone()];
            for (_, ys) in &self.series {
                row.push(format!("{:.4}", ys[i]));
            }
            t.row(row);
        }
        let mut out = t.render();
        for (name, ys) in &self.series {
            out.push_str(&format!("{:<18} {}\n", name, sparkline(ys)));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Unicode sparkline of a series.
pub fn sparkline(ys: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &y in ys {
        lo = lo.min(y);
        hi = hi.max(y);
    }
    if !lo.is_finite() || !hi.is_finite() || (hi - lo).abs() < 1e-12 {
        return "▄".repeat(ys.len());
    }
    ys.iter()
        .map(|&y| {
            let t = ((y - lo) / (hi - lo) * 7.0).round() as usize;
            BARS[t.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "bbbb", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["xxx".into(), "y".into(), "zz".into()]);
        let r = t.render();
        assert!(r.contains("Demo"));
        assert!(r.contains("xxx"));
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines.len() >= 5);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn figure_renders_sparkline() {
        let mut f = Figure::new("Fig", "x");
        f.set_x(&[1, 2, 3]);
        f.add_series("up", vec![0.0, 0.5, 1.0]);
        let r = f.render();
        assert!(r.contains('█'));
        assert!(r.contains("up"));
    }

    #[test]
    fn sparkline_degenerate() {
        assert_eq!(sparkline(&[1.0, 1.0]), "▄▄");
        assert_eq!(sparkline(&[]), "");
    }
}
