//! Bench harness regenerating every FIGURE of the paper's evaluation
//! (Figs. 3, 5, 7, 9, 10a, 10b) and timing the regeneration.

use prunemap::experiments as exp;
use prunemap::simulator::DeviceProfile;
use prunemap::util::bench::{bench, black_box, header};

fn main() {
    let dev = DeviceProfile::s10();
    println!("## paper figures (regeneration + timing)\n");

    exp::fig3().print();
    exp::fig5(&dev).print();
    for f in exp::fig7() {
        f.print();
    }
    for f in exp::fig9(&dev) {
        f.print();
    }
    exp::fig10a(&dev).print();
    exp::fig10b(&dev).print();

    println!("\n## timings\n");
    header();
    let budget = std::time::Duration::from_millis(300);
    bench("fig3_layer_stats", budget, || {
        black_box(exp::fig3());
    });
    bench("fig5_blocksize_tradeoff", budget, || {
        black_box(exp::fig5(&dev));
    });
    bench("fig7_pattern_vs_block_acc", budget, || {
        black_box(exp::fig7());
    });
    bench("fig9_conv_latency_sweep", budget, || {
        black_box(exp::fig9(&dev));
    });
    bench("fig10a_fc_latency", budget, || {
        black_box(exp::fig10a(&dev));
    });
    bench("fig10b_pattern_latency", budget, || {
        black_box(exp::fig10b(&dev));
    });
}
