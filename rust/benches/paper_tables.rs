//! Bench harness regenerating every TABLE of the paper's evaluation and
//! timing the regeneration (criterion is unavailable offline; see
//! util::bench).  Run with `cargo bench` — output doubles as the
//! reproduction record consumed by EXPERIMENTS.md.

use std::time::Duration;

use prunemap::experiments as exp;
use prunemap::simulator::DeviceProfile;
use prunemap::util::bench::{bench_n, black_box, header};

fn main() {
    let dev = DeviceProfile::s10();
    println!("## paper tables (regeneration + timing)\n");

    // print each table once (the reproduction record)...
    exp::table1().print();
    exp::table2(&dev).print();
    exp::table3().print();
    let t4 = exp::table4(&dev, true);
    t4.print();
    exp::table5(&dev).print();
    exp::table6().print();
    exp::table7().print();
    exp::ablation(&dev).print();

    // ...then time the generators
    println!("\n## timings\n");
    header();
    bench_n("table2_yolo", 5, || {
        black_box(exp::table2(&dev));
    });
    bench_n("table3_dw_ablation", 10, || {
        black_box(exp::table3());
    });
    bench_n("table4_main_quick", 2, || {
        black_box(exp::table4(&dev, true));
    });
    bench_n("table5_macs_levels", 3, || {
        black_box(exp::table5(&dev));
    });
    bench_n("table7_portability", 2, || {
        black_box(exp::table7());
    });
    let _ = Duration::ZERO;
}
