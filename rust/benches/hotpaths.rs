//! Hot-path micro-benchmarks: the inner loops the §Perf pass optimizes.
//! Mask generation, BCS/CSR conversion, row reorder, the batched
//! multi-threaded sparse execution engine (serial-vs-threaded,
//! spmv-vs-spmm, and the `spmm_simd_vs_scalar` /
//! `fused_vs_materialized_im2col` acceptance pairs, each emitting a
//! `BENCH {json}` record), the serving layer (the
//! `serve_coalesced_vs_one_request_per_run` session burst and the
//! multi-model front-door routing record
//! `routed_two_models_vs_two_sessions`), whole-network end-to-end
//! inference through the
//! graph executor (VGG-16 / MobileNet-V1 CIFAR at several batch sizes,
//! fused vs materialized im2col, with a measured-vs-modeled calibration
//! JSON record per network), latency-model build, GA tuning, one RL search
//! iteration, and (under `--cfg pjrt`, when artifacts exist) the PJRT
//! block-matmul execution.
//!
//! `cargo bench -- --threads N` overrides the engine worker count,
//! `--tile N` the fused-im2col tile width, and `--json-out F` writes the
//! collected `BENCH` comparison records to a JSON file.

use std::time::Duration;

use prunemap::accuracy::Assignment;
use prunemap::bench::records::ValueSink;
use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{map_rule_based, map_search_based, RuleConfig, SearchConfig};
use prunemap::models::{zoo, Dataset, LayerSpec};
use prunemap::pruning::{prune, PatternLibrary, Scheme};
use prunemap::rng::Rng;
use prunemap::runtime::graph::im2col::{im2col, Im2colPanels};
use prunemap::runtime::{CompiledNet, GraphExecutor, KernelChoice};
use prunemap::serve::{InferRequest, ModelRegistry, PreparedModel, Server, Session};
use prunemap::simulator::{measured_vs_modeled_network, DeviceProfile};
use prunemap::sparse::{permute_rows, reorder_rows, Bcs, Csr, Engine, SparseKernel};
use prunemap::tensor::Tensor;
use prunemap::util::bench::{
    bench, bench_n, black_box, emit_comparison, fmt_speedup, header, BenchStats,
};
use prunemap::util::cli::Args;

/// Masked + reordered GEMM view for one pruning layout.
fn layout(
    name: &'static str,
    scheme: Scheme,
    comp: f32,
    lib: &PatternLibrary,
    rng: &mut Rng,
) -> (&'static str, Tensor) {
    let t = match scheme {
        Scheme::Block { .. } | Scheme::Unstructured => {
            let w = Tensor::he_normal(&[1024, 1024], 1024, rng);
            let r = prune(&w, &scheme, comp, lib);
            w.hadamard(&r.mask)
        }
        _ => {
            let w = Tensor::he_normal(&[128, 128, 3, 3], 128 * 9, rng);
            let r = prune(&w, &scheme, comp, lib);
            w.hadamard(&r.mask).conv_to_gemm()
        }
    };
    let reordered = permute_rows(&t, &reorder_rows(&t));
    (name, reordered)
}

fn main() {
    let budget = Duration::from_millis(400);
    let dev = DeviceProfile::s10();
    let lib = PatternLibrary::default8();
    println!("## hot paths\n");
    header();

    // --- mask generation ------------------------------------------------
    let mut rng = Rng::new(1);
    let w4 = Tensor::he_normal(&[128, 128, 3, 3], 128 * 9, &mut rng);
    bench("prune_block_punched_128x128x3x3", budget, || {
        black_box(prune(&w4, &Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0, &lib));
    });
    bench("prune_pattern_128x128x3x3", budget, || {
        black_box(prune(&w4, &Scheme::Pattern, 8.0, &lib));
    });
    let w2 = Tensor::he_normal(&[1024, 1024], 1024, &mut rng);
    bench("prune_block_fc_1024x1024", budget, || {
        black_box(prune(&w2, &Scheme::Block { bp: 16, bq: 32 }, 8.0, &lib));
    });
    bench("prune_unstructured_1024x1024", budget, || {
        black_box(prune(&w2, &Scheme::Unstructured, 8.0, &lib));
    });

    // --- sparse formats ---------------------------------------------------
    let pruned = {
        let r = prune(&w4, &Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0, &lib);
        w4.hadamard(&r.mask).conv_to_gemm()
    };
    bench("bcs_from_dense_1152x128", budget, || {
        black_box(Bcs::from_dense(&pruned));
    });
    bench("csr_from_dense_1152x128", budget, || {
        black_box(Csr::from_dense(&pruned));
    });
    bench("reorder_rows_1152x128", budget, || {
        black_box(reorder_rows(&pruned));
    });
    let order = reorder_rows(&pruned);
    let reordered = permute_rows(&pruned, &order);
    let bcs = Bcs::from_dense(&reordered);
    let csr = Csr::from_dense(&reordered);
    let x: Vec<f32> = (0..pruned.shape()[1]).map(|i| (i as f32).sin()).collect();
    bench("bcs_spmv", budget, || {
        black_box(bcs.spmv(&x));
    });
    bench("csr_spmv", budget, || {
        black_box(csr.spmv(&x));
    });
    println!(
        "    storage: dense={}B csr={}B bcs={}B (bcs/csr={:.2})",
        reordered.len() * 4,
        csr.storage_bytes(),
        bcs.storage_bytes(),
        bcs.storage_bytes() as f64 / csr.storage_bytes() as f64
    );

    // --- execution engine: spmv vs spmm, serial vs threaded ----------------
    let args = Args::from_env();
    let threads = match args.get("threads") {
        Some(_) => args.engine_threads().expect("--threads expects an integer"),
        None => rayon::current_num_threads().max(4),
    };
    let tile = args
        .tile_cols(prunemap::sparse::DEFAULT_TILE_COLS)
        .expect("--tile expects an integer");
    // flushed to --json-out after EVERY comparison (not once at the end)
    // so a panic or Ctrl-C mid-run keeps the records collected so far
    let mut records = ValueSink::new(args.get("json-out").map(std::path::PathBuf::from));
    println!("\n## execution engine (threads = {threads}, tile = {tile})\n");
    header();
    let serial = Engine::serial();
    let threaded = Engine::new(threads).with_tile_cols(tile);
    let layouts = [
        layout("block8x8", Scheme::Block { bp: 8, bq: 8 }, 10.0, &lib, &mut rng),
        layout("pattern", Scheme::Pattern, 8.0, &lib, &mut rng),
        layout("unstructured", Scheme::Unstructured, 10.0, &lib, &mut rng),
    ];
    for (name, t) in &layouts {
        let kernel = Bcs::from_dense(t);
        let (rows, cols) = kernel.dims();
        let density = kernel.nnz() as f64 / (rows * cols) as f64;
        println!(
            "    {name}: {rows}x{cols}, {:.1}% dense, {} occurrence-runs, imbalance {:.3}",
            density * 100.0,
            kernel.work_units().len(),
            threaded.predicted_balance(&kernel).imbalance
        );
        let xv: Vec<f32> = (0..cols).map(|i| (i as f32).cos()).collect();
        bench(&format!("{name}_spmv_serial"), budget, || {
            black_box(serial.spmv(&kernel, &xv));
        });
        bench(&format!("{name}_spmv_threaded"), budget, || {
            black_box(threaded.spmv(&kernel, &xv));
        });
        for batch in [8usize, 32] {
            let xb: Vec<f32> = (0..cols * batch).map(|i| (i as f32 * 0.37).cos()).collect();
            bench(&format!("{name}_spmm_b{batch}_serial"), budget, || {
                black_box(serial.spmm(&kernel, &xb, batch));
            });
            bench(&format!("{name}_spmm_b{batch}_threaded"), budget, || {
                black_box(threaded.spmm(&kernel, &xb, batch));
            });
        }
    }

    // --- acceptance case: 1024x1024, ~10% dense, block-pruned, batch 32 ----
    let (_, accept) = &layouts[0];
    let kernel = Bcs::from_dense(accept);
    let cols = kernel.dims().1;
    let xb: Vec<f32> = (0..cols * 32).map(|i| (i as f32 * 0.11).sin()).collect();
    let s = bench("accept_block_1024_spmm_b32_serial", budget, || {
        black_box(kernel.spmm(&xb, 32));
    });
    let t = bench(
        &format!("accept_block_1024_spmm_b32_threads{threads}"),
        budget,
        || {
            black_box(threaded.spmm(&kernel, &xb, 32));
        },
    );
    report_speedup(&s, &t);

    // --- acceptance pair: SIMD batch lanes vs the scalar reference loop ----
    let scalar = bench("accept_block_1024_spmm_b32_scalar", budget, || {
        black_box(kernel.spmm_scalar(&xb, 32));
    });
    let (rec, sp) = emit_comparison("spmm_simd_vs_scalar_1024x1024_b32", &scalar, &s);
    records.push(rec).expect("flush bench record");
    println!("    simd/scalar speedup: {} (serial, batch 32)", fmt_speedup(sp));

    // --- acceptance pair: fused tile-order im2col vs materialized X --------
    // conv 128->128 3x3 SAME on 32x32, batch 8: the whole lowering cost,
    // expansion + spmm, on both paths
    let convw = {
        let w = Tensor::he_normal(&[128, 128, 3, 3], 128 * 9, &mut rng);
        let r = prune(&w, &Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0, &lib);
        w.hadamard(&r.mask).conv_to_gemm().transpose2() // [F, C*KH*KW]
    };
    let conv_kernel = Bcs::from_dense(&convw);
    let (cc, hh, ww, bb) = (128usize, 32usize, 32usize, 8usize);
    let act: Vec<f32> = (0..cc * bb * hh * ww)
        .map(|i| ((i % 13) as f32) * 0.3 - 1.8)
        .collect();
    let panels = Im2colPanels::new(&act, cc, hh, ww, bb, 3, 3, 1);
    let mut xmat = Vec::new();
    let mat = bench_n(&format!("conv128_b8_materialized_t{threads}"), 5, || {
        let (oh, ow) = im2col(&act, cc, hh, ww, bb, 3, 3, 1, &mut xmat);
        black_box(threaded.spmm(&conv_kernel, &xmat, bb * oh * ow));
    });
    let fus = bench_n(&format!("conv128_b8_fused_tile{tile}_t{threads}"), 5, || {
        black_box(threaded.spmm_fused(&conv_kernel, &panels));
    });
    let (rec, sp) = emit_comparison("fused_vs_materialized_im2col_conv128_b8", &mat, &fus);
    records.push(rec).expect("flush bench record");
    println!("    fused/materialized speedup: {}", fmt_speedup(sp));

    // --- whole-network graph executor (im2col conv + fused epilogues) ------
    println!("\n## graph executor: end-to-end pruned networks (threads = {threads})\n");
    header();
    let lat = LatencyModel::build(&dev);
    for (name, model) in [
        ("mobilenet_v1_cifar", zoo::mobilenet_v1(Dataset::Cifar10)),
        ("vgg16_cifar", zoo::vgg16(Dataset::Cifar10)),
    ] {
        let assigns: Vec<Assignment> = map_rule_based(&model, &lat, &RuleConfig::default());
        let net = CompiledNet::compile(&model, &assigns, 11, KernelChoice::Auto)
            .expect("compile network");
        let (c, h, w) = net.input_shape;
        println!(
            "    {name}: {} layers -> {} steps, {} arena slots, {} retained weights",
            net.layers.len(),
            net.steps.len(),
            net.num_slots,
            net.total_nnz()
        );
        let serial_exec = GraphExecutor::serial();
        let threaded_exec = GraphExecutor::new(threads).with_tile_cols(tile);
        let materialized_exec = GraphExecutor::new(threads).materialized();
        for batch in [1usize, 8] {
            let input: Vec<f32> = (0..batch * c * h * w)
                .map(|i| ((i % 19) as f32) * 0.21 - 1.9)
                .collect();
            let s = bench_n(&format!("{name}_infer_b{batch}_serial"), 3, || {
                black_box(serial_exec.run(&net, &input, batch).unwrap());
            });
            let t = bench_n(&format!("{name}_infer_b{batch}_threads{threads}"), 3, || {
                black_box(threaded_exec.run(&net, &input, batch).unwrap());
            });
            if batch == 8 {
                report_speedup(&s, &t);
                let m = bench_n(&format!("{name}_infer_b{batch}_materialized"), 3, || {
                    black_box(materialized_exec.run(&net, &input, batch).unwrap());
                });
                let (rec, sp) =
                    emit_comparison(&format!("fused_vs_materialized_{name}_b8"), &m, &t);
                records.push(rec).expect("flush bench record");
                println!("    fused/materialized speedup: {}", fmt_speedup(sp));
            }
        }
        // measured-vs-modeled calibration record (JSON via util::json) so
        // BENCH trajectories can track model-vs-reality drift across PRs
        let cmp = measured_vs_modeled_network(&model, &assigns, &dev, &net, 8, threads, 2)
            .expect("calibration run");
        println!("    calibration: {}", cmp.to_json().compact());
    }

    // --- serve session: dynamic micro-batching throughput ------------------
    // compile once, then push a burst of single-sample requests through the
    // session; baseline = blocking one-request-per-run round trips,
    // contender = pipelined submits the micro-batcher coalesces into
    // lane-aligned batches
    println!("\n## serve session: compile-once / serve-many (threads = {threads})\n");
    header();
    let prepared = PreparedModel::builder()
        .model("mobilenetv1")
        .dataset("cifar10")
        .method("rule")
        .seed(11)
        .build()
        .expect("prepare model");
    let sample = prepared.input_len();
    let mk_input = |tag: usize| -> Vec<f32> {
        (0..sample).map(|j| (((tag * 31 + j) % 17) as f32) * 0.25 - 2.0).collect()
    };
    let nreq = 48usize;
    let single = Session::builder(prepared.clone())
        .threads(threads)
        .max_batch(1)
        .max_wait(Duration::ZERO)
        .build();
    let one_per_run = bench_n(&format!("serve_one_per_run_{nreq}req_t{threads}"), 3, || {
        for tag in 0..nreq {
            black_box(single.infer(mk_input(tag)).unwrap());
        }
    });
    let coalescing = Session::builder(prepared.clone())
        .threads(threads)
        .max_batch(32)
        .max_wait(Duration::from_millis(5))
        .build();
    let coalesced = bench_n(&format!("serve_coalesced_b32_{nreq}req_t{threads}"), 3, || {
        let tickets: Vec<_> =
            (0..nreq).map(|tag| coalescing.submit(mk_input(tag)).unwrap()).collect();
        for t in tickets {
            black_box(t.wait().unwrap());
        }
    });
    let (rec, sp) =
        emit_comparison("serve_coalesced_vs_one_request_per_run", &one_per_run, &coalesced);
    records.push(rec).expect("flush bench record");
    let st = coalescing.stats();
    println!(
        "    coalesced/single speedup: {} ({} requests in {} runs, max coalesced {}, {} padded lanes)",
        fmt_speedup(sp),
        st.requests,
        st.runs,
        st.max_coalesced,
        st.padded_lanes
    );

    // --- serve front door: one routed process vs two isolated sessions -----
    // baseline = "two processes": each model behind its own independent
    // session, each serving its half of the burst; contender = one Server
    // routing the same interleaved burst across a two-model registry.
    // Same request count, same per-model batcher knobs — the delta is the
    // routing layer plus whatever coalescing the interleave changes.
    println!("\n## serve front door: two models, one process (threads = {threads})\n");
    header();
    let prepared_b = PreparedModel::builder()
        .model("proxy")
        .method("rule")
        .seed(11)
        .build()
        .expect("prepare proxy");
    let sample_b = prepared_b.input_len();
    let mk_input_b = |tag: usize| -> Vec<f32> {
        (0..sample_b).map(|j| (((tag * 13 + j) % 19) as f32) * 0.2 - 1.7).collect()
    };
    let half = nreq / 2;
    let sess_a = Session::builder(prepared.clone())
        .threads(threads)
        .max_batch(16)
        .max_wait(Duration::from_millis(5))
        .build();
    let sess_b = Session::builder(prepared_b.clone())
        .threads(threads)
        .max_batch(16)
        .max_wait(Duration::from_millis(5))
        .build();
    let isolated = bench_n(&format!("serve_two_isolated_sessions_{nreq}req"), 3, || {
        let ta: Vec<_> = (0..half).map(|tag| sess_a.submit(mk_input(tag)).unwrap()).collect();
        let tb: Vec<_> = (0..half).map(|tag| sess_b.submit(mk_input_b(tag)).unwrap()).collect();
        for t in ta.into_iter().chain(tb) {
            black_box(t.wait().unwrap());
        }
    });
    let registry = ModelRegistry::new();
    registry.insert("mobilenetv1", prepared.clone());
    registry.insert("proxy", prepared_b.clone());
    let server = Server::builder(registry)
        .threads(threads)
        .max_batch(16)
        .max_wait(Duration::from_millis(5))
        .build();
    let routed = bench_n(&format!("serve_routed_two_models_{nreq}req"), 3, || {
        let tickets: Vec<_> = (0..nreq)
            .map(|tag| {
                let req = if tag % 2 == 0 {
                    InferRequest::new("mobilenetv1", mk_input(tag))
                } else {
                    InferRequest::new("proxy", mk_input_b(tag))
                };
                server.submit(req).unwrap()
            })
            .collect();
        for t in tickets {
            black_box(t.wait().unwrap());
        }
    });
    let (rec, sp) = emit_comparison("routed_two_models_vs_two_sessions", &isolated, &routed);
    records.push(rec).expect("flush bench record");
    println!(
        "    routed/isolated speedup: {} (the cost of the routing layer if < 1)",
        fmt_speedup(sp)
    );

    // --- mapping machinery -------------------------------------------------
    println!();
    header();
    bench("latmodel_build_s10", Duration::from_secs(2), || {
        black_box(LatencyModel::build(&dev));
    });
    let layer = LayerSpec::conv("c", 3, 128, 128, 28, 1);
    let base = prunemap::simulator::ExecConfig::new(
        Scheme::BlockPunched { bf: 8, bc: 16 },
        8.0,
        &dev,
    );
    bench("ga_tune_layer", budget, || {
        let mut r = Rng::new(3);
        black_box(prunemap::compiler::tune_layer(
            &layer,
            &base,
            &dev,
            &prunemap::compiler::GaConfig::default(),
            &mut r,
        ));
    });
    // measured-vs-modeled hook: the engine measurement the cost model sits
    // beside (host CPU vs modeled mobile GPU — compare trends, not values)
    let cmp = prunemap::simulator::measured_vs_modeled(
        &layer,
        &base,
        &dev,
        &reordered,
        32,
        threads,
        5,
    );
    println!(
        "    measured-vs-modeled: modeled {:.4}ms (mobile, batch 1) | measured {:.4}ms (host, batch 32, {} threads)",
        cmp.modeled_ms, cmp.measured_ms, cmp.threads
    );
    let m = zoo::resnet18(Dataset::Cifar10);
    bench("rl_search_10_iters_resnet18", Duration::from_secs(2), || {
        black_box(map_search_based(
            &m,
            &dev,
            &SearchConfig { iterations: 10, samples: 4, ..Default::default() },
        ));
    });

    // --- PJRT execution (needs --cfg pjrt + `make artifacts`) --------------
    pjrt_bench();

    // BENCH comparison records were flushed to --json-out after each
    // comparison; the definitions-as-data successor to this binary is
    // `prunemap bench` over benches/defs/ (see benches/records/README.md)
    if let Some(path) = args.get("json-out") {
        println!("\nwrote {} record(s) to {path} (flushed incrementally)", records.len());
    }
}

/// Print the serial/threaded comparison the acceptance criteria track:
/// threaded should be >= 1.5x serial at batch 32 with >= 4 threads.
fn report_speedup(serial: &BenchStats, threaded: &BenchStats) {
    let speedup = serial.median.as_secs_f64() / threaded.median.as_secs_f64().max(1e-12);
    println!(
        "    serial/threaded speedup: {speedup:.2}x (target >= 1.5x) {}",
        if speedup >= 1.5 { "OK" } else { "BELOW TARGET" }
    );
}

#[cfg(pjrt)]
fn pjrt_bench() {
    use prunemap::runtime::{HostValue, Runtime};
    use std::time::Duration;
    match Runtime::open(Runtime::default_dir()) {
        Ok(rt) => {
            let exe = rt.load("block_matmul").expect("compile block_matmul");
            let sig = exe.signature().clone();
            let (mm, kk, nn) = (sig.m.unwrap(), sig.k.unwrap(), sig.n.unwrap());
            let mut rng = Rng::new(9);
            let x = HostValue::f32(&[mm, kk], (0..mm * kk).map(|_| rng.normal()).collect());
            let w = HostValue::f32(&[kk, nn], (0..kk * nn).map(|_| rng.normal()).collect());
            let mask = HostValue::f32(
                &[kk, nn],
                (0..kk * nn).map(|_| rng.bernoulli(0.25) as u8 as f32).collect(),
            );
            bench("pjrt_block_matmul_256x512x512", Duration::from_secs(2), || {
                black_box(exe.run(&[x.clone(), w.clone(), mask.clone()]).unwrap());
            });
        }
        Err(_) => println!("(skipping PJRT bench: run `make artifacts` first)"),
    }
}

#[cfg(not(pjrt))]
fn pjrt_bench() {
    println!("(skipping PJRT bench: build with RUSTFLAGS=\"--cfg pjrt\" and run `make artifacts`)");
}
