//! Hot-path micro-benchmarks: the inner loops the §Perf pass optimizes.
//! BCS conversion + SpMV, row reorder, mask generation, latency-model
//! build, GA tuning, one RL search iteration, and (when artifacts exist)
//! the PJRT block-matmul execution itself.

use std::time::Duration;

use prunemap::latmodel::LatencyModel;
use prunemap::mapping::{map_search_based, SearchConfig};
use prunemap::models::{zoo, Dataset, LayerSpec};
use prunemap::pruning::{prune, PatternLibrary, Scheme};
use prunemap::rng::Rng;
use prunemap::runtime::{HostValue, Runtime};
use prunemap::simulator::DeviceProfile;
use prunemap::sparse::{permute_rows, reorder_rows, Bcs, Csr};
use prunemap::tensor::Tensor;
use prunemap::util::bench::{bench, black_box, header};

fn main() {
    let budget = Duration::from_millis(400);
    let dev = DeviceProfile::s10();
    let lib = PatternLibrary::default8();
    println!("## hot paths\n");
    header();

    // --- mask generation ------------------------------------------------
    let mut rng = Rng::new(1);
    let w4 = Tensor::he_normal(&[128, 128, 3, 3], 128 * 9, &mut rng);
    bench("prune_block_punched_128x128x3x3", budget, || {
        black_box(prune(&w4, &Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0, &lib));
    });
    bench("prune_pattern_128x128x3x3", budget, || {
        black_box(prune(&w4, &Scheme::Pattern, 8.0, &lib));
    });
    let w2 = Tensor::he_normal(&[1024, 1024], 1024, &mut rng);
    bench("prune_block_fc_1024x1024", budget, || {
        black_box(prune(&w2, &Scheme::Block { bp: 16, bq: 32 }, 8.0, &lib));
    });
    bench("prune_unstructured_1024x1024", budget, || {
        black_box(prune(&w2, &Scheme::Unstructured, 8.0, &lib));
    });

    // --- sparse formats ---------------------------------------------------
    let pruned = {
        let r = prune(&w4, &Scheme::BlockPunched { bf: 8, bc: 16 }, 8.0, &lib);
        w4.hadamard(&r.mask).conv_to_gemm()
    };
    bench("bcs_from_dense_1152x128", budget, || {
        black_box(Bcs::from_dense(&pruned));
    });
    bench("csr_from_dense_1152x128", budget, || {
        black_box(Csr::from_dense(&pruned));
    });
    bench("reorder_rows_1152x128", budget, || {
        black_box(reorder_rows(&pruned));
    });
    let order = reorder_rows(&pruned);
    let reordered = permute_rows(&pruned, &order);
    let bcs = Bcs::from_dense(&reordered);
    let csr = Csr::from_dense(&reordered);
    let x: Vec<f32> = (0..pruned.shape()[1]).map(|i| (i as f32).sin()).collect();
    bench("bcs_spmv", budget, || {
        black_box(bcs.spmv(&x));
    });
    bench("csr_spmv", budget, || {
        black_box(csr.spmv(&x));
    });
    println!(
        "    storage: dense={}B csr={}B bcs={}B (bcs/csr={:.2})",
        reordered.len() * 4,
        csr.storage_bytes(),
        bcs.storage_bytes(),
        bcs.storage_bytes() as f64 / csr.storage_bytes() as f64
    );

    // --- mapping machinery -------------------------------------------------
    bench("latmodel_build_s10", Duration::from_secs(2), || {
        black_box(LatencyModel::build(&dev));
    });
    let layer = LayerSpec::conv("c", 3, 128, 128, 28, 1);
    let base = prunemap::simulator::ExecConfig::new(
        Scheme::BlockPunched { bf: 8, bc: 16 },
        8.0,
        &dev,
    );
    bench("ga_tune_layer", budget, || {
        let mut r = Rng::new(3);
        black_box(prunemap::compiler::tune_layer(
            &layer,
            &base,
            &dev,
            &prunemap::compiler::GaConfig::default(),
            &mut r,
        ));
    });
    let m = zoo::resnet18(Dataset::Cifar10);
    bench("rl_search_10_iters_resnet18", Duration::from_secs(2), || {
        black_box(map_search_based(
            &m,
            &dev,
            &SearchConfig { iterations: 10, samples: 4, ..Default::default() },
        ));
    });

    // --- PJRT execution (needs `make artifacts`) ---------------------------
    match Runtime::open(Runtime::default_dir()) {
        Ok(rt) => {
            let exe = rt.load("block_matmul").expect("compile block_matmul");
            let sig = exe.signature().clone();
            let (mm, kk, nn) = (sig.m.unwrap(), sig.k.unwrap(), sig.n.unwrap());
            let mut rng = Rng::new(9);
            let x = HostValue::f32(&[mm, kk], (0..mm * kk).map(|_| rng.normal()).collect());
            let w = HostValue::f32(&[kk, nn], (0..kk * nn).map(|_| rng.normal()).collect());
            let mask = HostValue::f32(
                &[kk, nn],
                (0..kk * nn).map(|_| rng.bernoulli(0.25) as u8 as f32).collect(),
            );
            bench("pjrt_block_matmul_256x512x512", Duration::from_secs(2), || {
                black_box(exe.run(&[x.clone(), w.clone(), mask.clone()]).unwrap());
            });
        }
        Err(_) => println!("(skipping PJRT bench: run `make artifacts` first)"),
    }
}
